//! Uniform time grids for numeric distribution work.

use crate::dist::ServiceDist;
use crate::flow::Workflow;
use crate::sched::server::Server;
use crate::sched::Allocation;

/// A uniform grid `t_k = k * dt`, `k = 0..n`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GridSpec {
    /// Step size.
    pub dt: f64,
    /// Number of points.
    pub n: usize,
}

impl GridSpec {
    /// Fixed grid.
    pub fn new(dt: f64, n: usize) -> GridSpec {
        assert!(dt > 0.0 && n > 8, "grid needs dt>0 and a few points");
        GridSpec { dt, n }
    }

    /// The canonical AOT grid (matches `python/compile/aot.py: G`).
    pub const AOT_N: usize = 1024;

    /// Hard cap on auto-sized horizons. A degenerate or heavy-tail
    /// fitted law can report a `quantile(0.9999)` that is infinite, NaN
    /// or astronomically large; summing those into a grid horizon used
    /// to yield `dt = inf` (every moment/quantile read off such a grid
    /// is garbage, and downstream grid merges panicked on it). Auto
    /// sizing now clamps the horizon to this cap and prints a
    /// diagnostic; scores on a clamped grid report low captured
    /// [`mass`](crate::compose::score::Score::mass), which is the
    /// signal callers already treat as "suspect grid".
    pub const MAX_HORIZON: f64 = 1e9;

    /// Clamp a raw auto-sizing horizon to `(0, MAX_HORIZON]`, surfacing
    /// a diagnostic when the raw value was unusable (non-finite, NaN or
    /// beyond the cap). The diagnostic goes through
    /// [`crate::util::warn::warn`], so library users can silence it
    /// ([`crate::util::warn::set_quiet`] or `DCFLOW_QUIET=1`).
    fn finite_horizon(raw: f64, what: &str) -> f64 {
        if raw.is_finite() && raw <= Self::MAX_HORIZON {
            return raw.max(1e-6);
        }
        crate::util::warn::warn(&format!(
            "{what} grid horizon {raw} is not usable \
             (degenerate or heavy-tail law?); clamping to {:e}",
            Self::MAX_HORIZON
        ));
        Self::MAX_HORIZON
    }

    /// Auto-size a grid for a workflow + allocation: the end-to-end
    /// support is at most the sum over serial depth of per-branch
    /// high quantiles; pad by 2x for convolution truncation safety.
    /// Non-finite horizons are clamped ([`GridSpec::MAX_HORIZON`]).
    pub fn auto(alloc: &Allocation, servers: &[Server]) -> GridSpec {
        let horizon = Self::finite_horizon(
            alloc
                .assigned_servers()
                .map(|sid| servers[sid].dist.quantile(0.9999))
                .sum::<f64>()
                * 2.0,
            "allocation",
        );
        GridSpec {
            dt: horizon / Self::AOT_N as f64,
            n: Self::AOT_N,
        }
    }

    /// Auto-size from an explicit set of laws (workflow-independent upper
    /// bound: every law could appear in series). Non-finite horizons are
    /// clamped ([`GridSpec::MAX_HORIZON`]).
    pub fn auto_for(dists: &[&ServiceDist]) -> GridSpec {
        let horizon = Self::finite_horizon(
            dists.iter().map(|d| d.quantile(0.9999)).sum::<f64>() * 2.0,
            "service-law",
        );
        GridSpec {
            dt: horizon / Self::AOT_N as f64,
            n: Self::AOT_N,
        }
    }

    /// Auto-size for a whole server pool on a workflow (used before an
    /// allocation exists, e.g. by the optimal exhaustive search).
    pub fn auto_pool(_wf: &Workflow, servers: &[Server]) -> GridSpec {
        let dists: Vec<&ServiceDist> = servers.iter().map(|s| &s.dist).collect();
        Self::auto_for(&dists)
    }

    /// Auto-size from the *response* laws of an allocation under a
    /// queueing model — response tails under load are much longer than
    /// service tails, so p99-style scores need this sizing. Falls back
    /// to [`GridSpec::auto`] if any queue is unstable. Non-finite
    /// horizons are clamped ([`GridSpec::MAX_HORIZON`]).
    pub fn auto_response(
        alloc: &crate::sched::Allocation,
        servers: &[Server],
        model: crate::sched::ResponseModel,
    ) -> GridSpec {
        use crate::sched::response::{response_dist, Response};
        let mut horizon = 0.0;
        for slot in 0..alloc.slot_server.len() {
            let service = &servers[alloc.server_for(slot)].dist;
            match response_dist(model, service, alloc.rate_for(slot)) {
                Response::Stable(d) => horizon += d.quantile(0.9999),
                Response::Unstable => return Self::auto(alloc, servers),
            }
        }
        let horizon = Self::finite_horizon(horizon * 1.25, "response-law");
        GridSpec {
            dt: horizon / Self::AOT_N as f64,
            n: Self::AOT_N,
        }
    }

    /// The largest response-aware grid over several allocations — lets a
    /// comparison score every candidate on a *common* grid.
    /// (`total_cmp`: a degenerate `dt` must not panic the merge.)
    pub fn auto_response_common(
        allocs: &[&crate::sched::Allocation],
        servers: &[Server],
        model: crate::sched::ResponseModel,
    ) -> GridSpec {
        allocs
            .iter()
            .map(|a| Self::auto_response(a, servers, model))
            .max_by(|a, b| a.dt.total_cmp(&b.dt))
            .unwrap_or(GridSpec {
                dt: 0.01,
                n: Self::AOT_N,
            })
    }

    /// Grid times.
    pub fn times(&self) -> Vec<f64> {
        (0..self.n).map(|k| k as f64 * self.dt).collect()
    }

    /// Grid times into a caller buffer of length [`GridSpec::n`] —
    /// same values as [`GridSpec::times`] without the allocation.
    pub fn times_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.n, "output grid must match");
        for (k, o) in out.iter_mut().enumerate() {
            *o = k as f64 * self.dt;
        }
    }

    /// Largest representable time.
    pub fn t_max(&self) -> f64 {
        (self.n - 1) as f64 * self.dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_are_uniform() {
        let g = GridSpec::new(0.5, 16);
        let t = g.times();
        assert_eq!(t.len(), 16);
        assert_eq!(t[0], 0.0);
        assert!((t[3] - 1.5).abs() < 1e-12);
        assert!((g.t_max() - 7.5).abs() < 1e-12);
        let mut into = vec![f64::NAN; 16];
        g.times_into(&mut into);
        assert_eq!(into, t);
    }

    #[test]
    fn auto_for_covers_tails() {
        let d1 = ServiceDist::exponential(1.0);
        let d2 = ServiceDist::delayed_exponential(0.5, 2.0);
        let g = GridSpec::auto_for(&[&d1, &d2]);
        assert_eq!(g.n, GridSpec::AOT_N);
        // t_max must exceed the sum of the 99.99% quantiles
        assert!(g.t_max() > d1.quantile(0.9999) + d2.quantile(0.9999));
    }

    #[test]
    #[should_panic(expected = "grid needs")]
    fn rejects_degenerate() {
        GridSpec::new(0.0, 100);
    }

    #[test]
    fn heavy_tail_horizon_is_clamped_finite() {
        // a pareto law with lam << 1 has a finite but astronomical
        // 99.99% quantile; the auto grid used to inherit it as a
        // garbage dt. It must clamp to MAX_HORIZON instead.
        let heavy = ServiceDist::delayed_pareto(0.05, 0.0);
        assert!(heavy.quantile(0.9999) > GridSpec::MAX_HORIZON);
        let g = GridSpec::auto_for(&[&heavy]);
        assert!(g.dt.is_finite() && g.dt > 0.0);
        assert!(g.t_max() <= GridSpec::MAX_HORIZON);
        // a sane companion law still gets a sane grid
        let tame = ServiceDist::exponential(2.0);
        let g2 = GridSpec::auto_for(&[&tame]);
        assert!(g2.t_max() < 100.0);
    }

    #[test]
    fn infinite_horizon_is_clamped_finite() {
        // non-finite inputs (an inf quantile from a degenerate fit) must
        // never produce dt = inf
        assert_eq!(
            GridSpec::MAX_HORIZON,
            super::GridSpec::finite_horizon(f64::INFINITY, "test")
        );
        assert_eq!(
            GridSpec::MAX_HORIZON,
            super::GridSpec::finite_horizon(f64::NAN, "test")
        );
        // tiny-but-positive raw horizons keep the 1e-6 floor
        assert_eq!(1e-6, super::GridSpec::finite_horizon(0.0, "test"));
    }
}
