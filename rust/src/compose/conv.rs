//! Serial composition: truncated PDF convolution (paper Eq. 1–2).
//!
//! `out[k] = dt * ( sum_{j<=k} f[j] g[k-j] - (f[0]g[k] + f[k]g[0])/2 )`
//!
//! — the trapezoid rule for the convolution integral, matching
//! `python/compile/kernels/ref.py::conv_pdf_ref` (and therefore the L1
//! pallas kernel) exactly. Two backends:
//!
//! * [`conv_direct`] — O(G²) triangle sum; cache-friendly for small G,
//!   bit-stable, used as the oracle;
//! * [`conv_fft`]    — O(G log G) via [`super::fft`]; the native hot path.

use super::fft::{convolve_real, convolve_real_into};
use super::scratch::Scratch;

/// Direct O(G²) truncated convolution with trapezoid correction.
pub fn conv_direct(f: &[f64], g: &[f64], dt: f64) -> Vec<f64> {
    assert_eq!(f.len(), g.len(), "grids must match");
    let mut out = vec![0.0; f.len()];
    conv_direct_into(f, g, dt, &mut out);
    out
}

/// [`conv_direct`] into a caller buffer (`out.len()` must equal the
/// grid) — the same triangle sum on the same operands, bit-identical.
pub fn conv_direct_into(f: &[f64], g: &[f64], dt: f64, out: &mut [f64]) {
    assert_eq!(f.len(), g.len(), "grids must match");
    let n = f.len();
    assert_eq!(out.len(), n, "output grid must match");
    out.fill(0.0);
    for (j, &fj) in f.iter().enumerate() {
        if fj == 0.0 {
            continue;
        }
        // out[k] += f[j] * g[k-j] for k >= j
        for (gi, o) in g[..n - j].iter().zip(out[j..].iter_mut()) {
            *o += fj * gi;
        }
    }
    endpoint_correct(out, f, g, dt);
}

/// FFT-backed truncated convolution with trapezoid correction.
pub fn conv_fft(f: &[f64], g: &[f64], dt: f64) -> Vec<f64> {
    assert_eq!(f.len(), g.len(), "grids must match");
    let n = f.len();
    let full = convolve_real(f, g);
    let mut out = full[..n].to_vec();
    endpoint_correct(&mut out, f, g, dt);
    out
}

/// [`conv_fft`] into a caller buffer with the complex work buffers
/// borrowed from `scratch` — bit-identical to the allocating form
/// (identical FFT size and schedule; see
/// [`convolve_real_into`]).
pub fn conv_fft_into(f: &[f64], g: &[f64], dt: f64, out: &mut [f64], scratch: &mut Scratch) {
    assert_eq!(f.len(), g.len(), "grids must match");
    assert_eq!(out.len(), f.len(), "output grid must match");
    convolve_real_into(f, g, out, scratch);
    endpoint_correct(out, f, g, dt);
}

#[inline]
fn endpoint_correct(out: &mut [f64], f: &[f64], g: &[f64], dt: f64) {
    let f0 = f[0];
    let g0 = g[0];
    for ((o, &fk), &gk) in out.iter_mut().zip(f.iter()).zip(g.iter()) {
        *o = dt * (*o - 0.5 * (f0 * gk + fk * g0));
    }
}

/// Grid size below which the O(G²) direct path beats the FFT on this
/// class of CPU (measured in `cargo bench --bench perf_hotpath`: direct
/// wins ≤ ~1.5k points thanks to cache locality and the early-exit on
/// leading zeros; FFT wins 3×+ at 4096).
pub const DIRECT_FFT_CROSSOVER: usize = 1536;

/// Backend-auto truncated convolution: direct for small grids, FFT for
/// large ones. This is the native hot path's default.
pub fn conv_auto(f: &[f64], g: &[f64], dt: f64) -> Vec<f64> {
    if f.len() <= DIRECT_FFT_CROSSOVER {
        conv_direct(f, g, dt)
    } else {
        conv_fft(f, g, dt)
    }
}

/// [`conv_auto`] into a caller buffer: the same crossover, dispatched
/// to [`conv_direct_into`] / [`conv_fft_into`], bit-identical to the
/// allocating form. This is what the scratch scoring path
/// ([`super::score::score_allocation_scratch`]) folds serial stacks
/// with.
pub fn conv_auto_into(f: &[f64], g: &[f64], dt: f64, out: &mut [f64], scratch: &mut Scratch) {
    if f.len() <= DIRECT_FFT_CROSSOVER {
        conv_direct_into(f, g, dt, out);
    } else {
        conv_fft_into(f, g, dt, out, scratch);
    }
}

/// Fold a serial stack of PDFs (first element composed with the rest).
/// Uses the auto backend; direct/fft are exposed for testing.
pub fn serial_compose(pdfs: &[Vec<f64>], dt: f64) -> Vec<f64> {
    assert!(!pdfs.is_empty());
    let mut acc = pdfs[0].clone();
    for p in &pdfs[1..] {
        acc = conv_auto(&acc, p, dt);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compose::analytic;
    use crate::dist::ServiceDist;
    use crate::util::prop;

    #[test]
    fn direct_equals_fft_property() {
        prop::run("direct conv == fft conv", 25, |g| {
            let n = *g.choose(&[64usize, 128, 200, 256]);
            let dt = g.f64_in(0.01, 0.1);
            let a = g.vec_of(n, |g| g.f64_in(0.0, 2.0));
            let b = g.vec_of(n, |g| g.f64_in(0.0, 2.0));
            let d = conv_direct(&a, &b, dt);
            let f = conv_fft(&a, &b, dt);
            for (x, y) in d.iter().zip(f.iter()) {
                assert!((x - y).abs() < 1e-9 * (1.0 + x.abs()), "{x} vs {y}");
            }
        });
    }

    #[test]
    fn conv_commutes() {
        prop::run("conv commutes", 20, |g| {
            let n = 128;
            let dt = 0.05;
            let a = g.vec_of(n, |g| g.f64_in(0.0, 1.0));
            let b = g.vec_of(n, |g| g.f64_in(0.0, 1.0));
            let ab = conv_fft(&a, &b, dt);
            let ba = conv_fft(&b, &a, dt);
            for (x, y) in ab.iter().zip(ba.iter()) {
                assert!((x - y).abs() < 1e-9);
            }
        });
    }

    #[test]
    fn conv_associates() {
        // (a*b)*c == a*(b*c) on the shared grid (up to truncation noise in
        // the high tail, so compare the low 3/4 of the grid)
        let n = 2048;
        let dt = 0.00625;
        let t: Vec<f64> = (0..n).map(|k| k as f64 * dt).collect();
        let pdf = |lam: f64| -> Vec<f64> { t.iter().map(|&x| lam * (-lam * x).exp()).collect() };
        let (a, b, c) = (pdf(3.0), pdf(5.0), pdf(7.0));
        let left = conv_fft(&conv_fft(&a, &b, dt), &c, dt);
        let right = conv_fft(&a, &conv_fft(&b, &c, dt), dt);
        // the trapezoid endpoint correction is O(dt^2)-non-associative in
        // the first cells; compare in integral (L1) norm and pointwise
        // away from the origin
        let l1: f64 = left
            .iter()
            .zip(right.iter())
            .map(|(x, y)| (x - y).abs())
            .sum::<f64>()
            * dt;
        assert!(l1 < 2e-3, "L1 gap {l1}");
        for k in 8..3 * n / 4 {
            assert!(
                (left[k] - right[k]).abs() < 1e-3,
                "k={k}: {} vs {}",
                left[k],
                right[k]
            );
        }
    }

    #[test]
    fn matches_hypoexponential_closed_form() {
        // paper Eq. 2 via analytic::hypoexp_cdf
        let (n, dt) = (2048, 0.01);
        let d1 = ServiceDist::exponential(2.0);
        let d2 = ServiceDist::exponential(5.0);
        let out = conv_fft(&d1.pdf_grid(dt, n), &d2.pdf_grid(dt, n), dt);
        let cdf = crate::compose::moments::cdf_from_pdf(&out, dt);
        for k in (0..n).step_by(97) {
            let want = analytic::hypoexp_cdf(k as f64 * dt, &[2.0, 5.0]);
            assert!(
                (cdf[k] - want).abs() < 5e-3,
                "k={k}: {} vs {want}",
                cdf[k]
            );
        }
    }

    #[test]
    fn erlang_stack() {
        // 4 iid Exp(2) == Erlang(4, 2): mean 2.0, var 1.0
        let (n, dt) = (2048, 0.005);
        let d = ServiceDist::exponential(2.0);
        let stack: Vec<Vec<f64>> = (0..4).map(|_| d.pdf_grid(dt, n)).collect();
        let out = serial_compose(&stack, dt);
        let (mean, var) = crate::compose::moments::moments(&out, dt);
        assert!((mean - 2.0).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn mass_preserved() {
        let (n, dt) = (2048, 0.01);
        let a = ServiceDist::exponential(3.0).pdf_grid(dt, n);
        let b = ServiceDist::exponential(5.0).pdf_grid(dt, n);
        let out = conv_fft(&a, &b, dt);
        let mass: f64 = out.iter().sum::<f64>() * dt;
        assert!((mass - 1.0).abs() < 0.01, "mass {mass}");
    }

    #[test]
    #[should_panic(expected = "grids must match")]
    fn rejects_mismatched_grids() {
        conv_fft(&[1.0; 8], &[1.0; 16], 0.1);
    }

    #[test]
    fn into_variants_are_bit_identical() {
        // the scratch path must not perturb a single ulp, on both sides
        // of the direct/FFT crossover
        let mut scratch = Scratch::new();
        for n in [200usize, DIRECT_FFT_CROSSOVER + 64] {
            let dt = 0.01;
            let d1 = ServiceDist::exponential(2.0).pdf_grid(dt, n);
            let d2 = ServiceDist::exponential(5.0).pdf_grid(dt, n);
            let want = conv_auto(&d1, &d2, dt);
            let mut got = vec![f64::NAN; n];
            conv_auto_into(&d1, &d2, dt, &mut got, &mut scratch);
            for (k, (x, y)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n} k={k}: {x} vs {y}");
            }
            // and the explicit backends agree with their into twins
            let mut direct = vec![0.0; n];
            conv_direct_into(&d1, &d2, dt, &mut direct);
            assert_eq!(direct, conv_direct(&d1, &d2, dt));
            let mut fft = vec![0.0; n];
            conv_fft_into(&d1, &d2, dt, &mut fft, &mut scratch);
            assert_eq!(fft, conv_fft(&d1, &d2, dt));
        }
        // warm scratch ⇒ repeated FFT convs allocate nothing
        let n = DIRECT_FFT_CROSSOVER + 64;
        let d = ServiceDist::exponential(3.0).pdf_grid(0.01, n);
        let mut out = vec![0.0; n];
        conv_fft_into(&d, &d, 0.01, &mut out, &mut scratch);
        let warm = scratch.buffer_allocs();
        for _ in 0..4 {
            conv_fft_into(&d, &d, 0.01, &mut out, &mut scratch);
        }
        assert_eq!(scratch.buffer_allocs(), warm);
    }
}
