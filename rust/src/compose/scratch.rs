//! Reusable kernel-buffer stash: the allocation-free hot-loop arena.
//!
//! Every grid kernel in [`super`] has an `*_into` variant that writes
//! into caller-provided buffers instead of allocating fresh `Vec`s per
//! candidate. [`Scratch`] is where those buffers live between calls —
//! a stash of real-valued and complex grid buffers (the pattern of
//! timely's `sort` crate stashes): `take_*` pops a buffer and sizes it,
//! `put_*` returns it for the next kernel. A long-lived worker (see
//! [`super::fabric::ScoringPool`]) keeps one `Scratch` for its whole
//! lifetime, so after the first candidate of a given grid shape has
//! warmed the stash, scoring performs **zero stash-buffer allocations
//! per candidate** — observable through [`Scratch::buffer_allocs`],
//! which the allocation-discipline tests pin.
//!
//! What the counters do *not* cover (by design, for bit-identity with
//! the serial reference): the returned [`Score`](super::score::Score)
//! owns its `pdf` vector (one `to_vec` per scored candidate), and
//! response-law construction inside
//! [`response_dist`](crate::sched::response::response_dist) builds a
//! small per-queue `ServiceDist`. Those are the only per-candidate
//! heap allocations left on the pooled path; every O(grid) working
//! buffer comes from the stash.

use crate::compose::fft::C64;

/// A stash of reusable grid buffers with allocation accounting.
///
/// Buffers are handed out by value (`take_*`) and returned (`put_*`);
/// a taken buffer that is never returned is simply lost to the stash
/// (the next `take` re-creates one and the counters show it). Distinct
/// buffer lengths coexist: `take_*` re-sizes whatever buffer it pops,
/// counting a [`Scratch::grown`] event only when the pop had to grow
/// its capacity.
#[derive(Debug, Default)]
pub struct Scratch {
    f64s: Vec<Vec<f64>>,
    c64s: Vec<Vec<C64>>,
    created: usize,
    grown: usize,
}

impl Scratch {
    /// An empty stash (no buffers warmed yet).
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// A zero-filled `f64` buffer of length `n`, reused from the stash
    /// when possible.
    pub fn take_f64(&mut self, n: usize) -> Vec<f64> {
        match self.f64s.pop() {
            Some(mut buf) => {
                if buf.capacity() < n {
                    self.grown += 1;
                }
                buf.clear();
                buf.resize(n, 0.0);
                buf
            }
            None => {
                self.created += 1;
                vec![0.0; n]
            }
        }
    }

    /// Return an `f64` buffer to the stash.
    pub fn put_f64(&mut self, buf: Vec<f64>) {
        self.f64s.push(buf);
    }

    /// A zero-filled complex buffer of length `n`, reused from the
    /// stash when possible (zero = [`C64::default`]).
    pub fn take_c64(&mut self, n: usize) -> Vec<C64> {
        match self.c64s.pop() {
            Some(mut buf) => {
                if buf.capacity() < n {
                    self.grown += 1;
                }
                buf.clear();
                buf.resize(n, C64::default());
                buf
            }
            None => {
                self.created += 1;
                vec![C64::default(); n]
            }
        }
    }

    /// Return a complex buffer to the stash.
    pub fn put_c64(&mut self, buf: Vec<C64>) {
        self.c64s.push(buf);
    }

    /// Buffers created because the stash was empty at `take` time.
    pub fn created(&self) -> usize {
        self.created
    }

    /// Stashed buffers whose capacity had to grow at `take` time.
    pub fn grown(&self) -> usize {
        self.grown
    }

    /// Total heap events the stash has performed (created + grown) —
    /// the number the allocation-discipline tests assert stays flat
    /// once the hot loop is warm.
    pub fn buffer_allocs(&self) -> usize {
        self.created + self.grown
    }

    /// Buffers currently parked in the stash (diagnostics).
    pub fn parked(&self) -> usize {
        self.f64s.len() + self.c64s.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_and_sized() {
        let mut s = Scratch::new();
        let mut a = s.take_f64(8);
        assert_eq!(a, vec![0.0; 8]);
        a[3] = 7.0;
        s.put_f64(a);
        // the recycled buffer must come back clean
        let b = s.take_f64(8);
        assert_eq!(b, vec![0.0; 8]);
        assert_eq!(s.created(), 1, "second take reuses the first buffer");
        assert_eq!(s.grown(), 0);
    }

    #[test]
    fn warm_stash_allocates_nothing() {
        let mut s = Scratch::new();
        for _ in 0..3 {
            let a = s.take_f64(64);
            let b = s.take_f64(64);
            let c = s.take_c64(128);
            s.put_f64(a);
            s.put_f64(b);
            s.put_c64(c);
        }
        // 2 f64 + 1 c64 created on the first pass, nothing after
        assert_eq!(s.buffer_allocs(), 3);
        assert_eq!(s.parked(), 3);
    }

    #[test]
    fn growing_a_buffer_is_counted() {
        let mut s = Scratch::new();
        let a = s.take_f64(16);
        s.put_f64(a);
        let big = s.take_f64(1024); // must grow the 16-cap buffer
        assert_eq!(big.len(), 1024);
        assert_eq!(s.created(), 1);
        assert_eq!(s.grown(), 1);
        s.put_f64(big);
        // shrinking re-takes never grow
        let small = s.take_f64(16);
        assert_eq!(small.len(), 16);
        assert_eq!(s.grown(), 1);
    }

    #[test]
    fn c64_recycles_to_default() {
        let mut s = Scratch::new();
        let mut z = s.take_c64(4);
        z[0] = C64::new(1.0, -1.0);
        s.put_c64(z);
        let z2 = s.take_c64(4);
        assert!(z2.iter().all(|c| c.re == 0.0 && c.im == 0.0));
        assert_eq!(s.buffer_allocs(), 1);
    }
}
