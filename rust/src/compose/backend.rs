//! The pluggable scoring seam: one trait every predictor sits behind.
//!
//! The paper's allocation algorithms repeatedly query a response-time
//! *predictor* (mean/variance/p99 of the end-to-end law under a
//! candidate allocation). [`ScoreBackend`] abstracts that predictor so
//! the [`Planner`](crate::plan::Planner), the refinement and exhaustive
//! search engines, and the multi-job partitioner all evaluate against
//! an injected backend instead of a hard-wired free function:
//!
//! * [`AnalyticBackend`] — the native composition engine
//!   ([`score_allocation_with`]), exact and allocation-shaped; the
//!   default everywhere;
//! * [`RuntimeBackend`](crate::runtime::scorer::RuntimeBackend) — the
//!   batched PJRT/AOT scorer folded in as just another implementation
//!   (lives in [`crate::runtime::scorer`]);
//! * [`EmpiricalBackend`] — scores against *measured* laws fitted from
//!   [`crate::dist::empirical`] samples instead of the believed pool,
//!   the "swap the analytic model for data" move of the runtime-variation
//!   literature;
//! * [`ShardedBackend`] — a combinator, not a predictor: wraps any of
//!   the above (or a custom backend) and fans each `score_batch` wave
//!   onto the persistent scoring fabric
//!   ([`ScoringPool`](crate::compose::fabric::ScoringPool); a
//!   spawn-per-wave scoped pool remains as the
//!   [`Dispatch::SpawnPerWave`] fallback), preserving input order and
//!   returning bit-identical scores to the inner backend run serially;
//! * [`AsyncScoreBackend`] — the pipelining combinator behind the live
//!   re-planning service ([`crate::serve`]): chunks flow through the
//!   fabric with a bounded number in flight, and
//!   [`AsyncScoreBackend::score_stream`] keeps waves scoring *while the
//!   caller is still enumerating candidates* — results are reassembled
//!   in input order and stay bit-identical to the inner backend run
//!   serially.
//!
//! Custom predictors (learned models, remote services) implement the
//! same trait and plug into
//! [`Planner::backend`](crate::plan::Planner::backend).
//!
//! ```
//! use dcflow::prelude::*;
//!
//! let wf = Workflow::fig6();
//! let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
//!
//! // The default planner scores through AnalyticBackend; injecting it
//! // explicitly is identical, bit for bit.
//! let backend = AnalyticBackend;
//! let plan = Planner::new(&wf, &servers)
//!     .backend(&backend)
//!     .plan(&SdccPolicy)
//!     .expect("fig6 is feasible");
//! assert!(plan.score.mean > 0.0);
//! ```

use std::borrow::Cow;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::compose::fabric::{FabricStats, ScoringPool};
use crate::compose::grid::GridSpec;
use crate::compose::score::{score_allocation_scratch, score_allocation_with, Score};
use crate::compose::scratch::Scratch;
use crate::dist::empirical::Empirical;
use crate::dist::fit::select_family;
use crate::dist::ServiceDist;
use crate::flow::Workflow;
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::Allocation;

/// A response-time predictor: maps (workflow, allocation, pool, grid,
/// queueing model) to a [`Score`]. Implementations must return
/// [`Score::unstable`]-style infinite scores (not panic) when a queue
/// in the allocation diverges, so search loops can skip the candidate.
///
/// Methods take `&self`; implementations that mutate internal state
/// (artifact caches, device handles) use interior mutability — see
/// [`RuntimeBackend`](crate::runtime::scorer::RuntimeBackend).
pub trait ScoreBackend {
    /// Short stable name for diagnostics and CSV rows.
    fn name(&self) -> &str;

    /// Score one allocation on `grid` under `model`.
    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score;

    /// Score a wave of candidate allocations (the optimizer's inner
    /// loop). The default maps [`ScoreBackend::score`] over the slice;
    /// batched implementations override this with one fused evaluation.
    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        allocs
            .iter()
            .map(|a| self.score(wf, a, servers, grid, model))
            .collect()
    }

    /// [`ScoreBackend::score_batch`] with a caller-provided [`Scratch`]
    /// arena for intermediate kernel buffers — the entry point the
    /// scoring fabric's workers use, so one long-lived arena serves
    /// every candidate a worker ever scores. **Must be bit-identical to
    /// [`ScoreBackend::score_batch`]** on the same inputs; the default
    /// simply ignores the scratch and delegates, which is trivially so.
    /// Backends with an allocation-free hot loop override it (see
    /// [`AnalyticBackend`], [`EmpiricalBackend`]).
    fn score_batch_scratch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
        scratch: &mut Scratch,
    ) -> Vec<Score> {
        let _ = scratch;
        self.score_batch(wf, allocs, servers, grid, model)
    }

    /// Counter snapshot of this backend's scoring fabric, when it has
    /// one — `None` (the default) for plain predictors. The sharded
    /// combinator reports pool/queue/scratch counters here; they flow
    /// into [`SwapStats`](crate::sched::multijob::SwapStats) and the
    /// benchmark JSON.
    fn fabric_stats(&self) -> Option<FabricStats> {
        None
    }

    /// The pool this backend effectively scores against, when it
    /// differs from the believed one — `None` (the default) means the
    /// believed laws are the scoring laws. Grid auto-sizing consults
    /// this so that a backend substituting *longer-tailed* measured
    /// laws (see [`EmpiricalBackend`]) gets an evaluation grid that
    /// covers those tails instead of silently truncating them.
    fn scoring_pool(&self, servers: &[Server]) -> Option<Vec<Server>> {
        let _ = servers;
        None
    }

    /// [`ScoreBackend::scoring_pool`] resolved against the believed
    /// pool: the substituted pool when the backend has one, the
    /// believed slice otherwise. This is the form grid-sizing call
    /// sites consume.
    fn resolve_scoring_pool<'s>(&self, servers: &'s [Server]) -> Cow<'s, [Server]> {
        match self.scoring_pool(servers) {
            Some(pool) => Cow::Owned(pool),
            None => Cow::Borrowed(servers),
        }
    }
}

/// The native analytic predictor: serial composition by PDF
/// convolution, parallel composition by CDF product, moments and
/// quantiles read off the grid — a thin [`ScoreBackend`] wrapper over
/// [`score_allocation_with`]. This is the default backend of every
/// [`Planner`](crate::plan::Planner) and the cross-check oracle for all
/// other backends.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AnalyticBackend;

impl ScoreBackend for AnalyticBackend {
    fn name(&self) -> &str {
        "analytic"
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        score_allocation_with(wf, alloc, servers, grid, model)
    }

    /// Allocation-free batch path: every candidate scores through
    /// [`score_allocation_scratch`], bit-identical to the allocating
    /// form (the fabric workers' hot loop).
    fn score_batch_scratch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
        scratch: &mut Scratch,
    ) -> Vec<Score> {
        allocs
            .iter()
            .map(|a| score_allocation_scratch(wf, a, servers, grid, model, scratch))
            .collect()
    }
}

/// Scores against *measured* service laws instead of the believed pool.
///
/// Each server with an attached sample set (raw observations or a
/// [`Empirical`] window) has its law re-fitted to the best Table-1
/// family ([`select_family`]) at construction; scoring substitutes the
/// fitted law for the believed one and runs the analytic engine.
/// Servers without samples keep their believed laws, so an empty
/// backend is bit-identical to [`AnalyticBackend`].
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::tandem(2, 1.0);
/// let believed = Server::pool_exponential(&[3.0, 4.0]);
/// // server 0 actually serves at rate ~6: feed measurements in
/// let samples: Vec<f64> = (1..400).map(|i| (i as f64 / 400.0_f64).ln() / -6.0).collect();
/// let backend = EmpiricalBackend::new().with_samples(0, &samples);
/// let plan = Planner::new(&wf, &believed)
///     .backend(&backend)
///     .plan(&SdccPolicy)
///     .expect("feasible");
/// // measured server 0 is faster than believed => better mean than the
/// // purely-believed score
/// let believed_plan = Planner::new(&wf, &believed).plan(&SdccPolicy).unwrap();
/// assert!(plan.score.mean < believed_plan.score.mean);
/// ```
#[derive(Clone, Debug, Default)]
pub struct EmpiricalBackend {
    /// Fitted law per server id; `None` = keep the believed law.
    fitted: Vec<Option<ServiceDist>>,
}

impl EmpiricalBackend {
    /// Backend with no measurements (behaves like [`AnalyticBackend`]).
    pub fn new() -> EmpiricalBackend {
        EmpiricalBackend { fitted: Vec::new() }
    }

    /// Attach raw observed service times for `server_id` (fits the best
    /// Table-1 family immediately). Builder-style; panics on an empty
    /// sample slice.
    #[must_use]
    pub fn with_samples(mut self, server_id: usize, samples: &[f64]) -> EmpiricalBackend {
        assert!(!samples.is_empty(), "empirical backend needs samples");
        if self.fitted.len() <= server_id {
            self.fitted.resize(server_id + 1, None);
        }
        let (_, law, _) = select_family(samples);
        self.fitted[server_id] = Some(law);
        self
    }

    /// Attach an [`Empirical`] window (e.g. a monitor's sliding window)
    /// for `server_id`.
    #[must_use]
    pub fn with_empirical(self, server_id: usize, emp: &Empirical) -> EmpiricalBackend {
        self.with_samples(server_id, emp.sorted())
    }

    /// The fitted law for a server, if measurements were attached.
    pub fn law_for(&self, server_id: usize) -> Option<&ServiceDist> {
        self.fitted.get(server_id).and_then(|l| l.as_ref())
    }

    /// Number of servers with measured (fitted) laws.
    pub fn measured_servers(&self) -> usize {
        self.fitted.iter().filter(|l| l.is_some()).count()
    }

    /// The believed pool with measured laws substituted in.
    fn effective_pool(&self, servers: &[Server]) -> Vec<Server> {
        servers
            .iter()
            .map(|s| match self.law_for(s.id) {
                Some(law) => Server::new(s.id, law.clone()),
                None => s.clone(),
            })
            .collect()
    }
}

impl ScoreBackend for EmpiricalBackend {
    fn name(&self) -> &str {
        "empirical"
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        match self.scoring_pool(servers) {
            None => score_allocation_with(wf, alloc, servers, grid, model),
            Some(pool) => score_allocation_with(wf, alloc, &pool, grid, model),
        }
    }

    /// One substituted pool per wave (not per candidate — the pool does
    /// not depend on the allocation).
    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        let scoring = self.resolve_scoring_pool(servers);
        allocs
            .iter()
            .map(|a| score_allocation_with(wf, a, &scoring, grid, model))
            .collect()
    }

    /// Same one-substitution-per-wave shape as
    /// [`EmpiricalBackend::score_batch`], on the allocation-free scorer.
    fn score_batch_scratch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
        scratch: &mut Scratch,
    ) -> Vec<Score> {
        let scoring = self.resolve_scoring_pool(servers);
        allocs
            .iter()
            .map(|a| score_allocation_scratch(wf, a, &scoring, grid, model, scratch))
            .collect()
    }

    fn scoring_pool(&self, servers: &[Server]) -> Option<Vec<Server>> {
        if self.fitted.iter().all(|l| l.is_none()) {
            return None;
        }
        Some(self.effective_pool(servers))
    }
}

/// How a [`ShardedBackend`] splits a `score_batch` wave into per-worker
/// chunks. Chunking only affects scheduling granularity, never results:
/// every policy yields the same scores in the same order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// One contiguous chunk per shard (`ceil(wave / shards)` candidates
    /// each) — minimal coordination, the default.
    Even,
    /// Fixed candidates per chunk (values `< 1` are treated as 1).
    /// Smaller chunks load-balance waves whose candidates have very
    /// uneven cost (e.g. mixed stable/unstable allocations) at the
    /// price of more queue traffic — and of repeating any per-wave
    /// setup the inner backend does per chunk (e.g.
    /// [`EmpiricalBackend`] re-derives its substituted scoring pool
    /// once per `score_batch` call). Prefer [`ChunkPolicy::Even`],
    /// which bounds that overhead at the shard count, unless a profile
    /// says otherwise.
    Fixed(usize),
}

/// How a [`ShardedBackend`] executes the chunks of a parallel wave.
/// Both modes produce bit-identical results (property-tested in
/// `tests/fabric_equivalence.rs`); the choice is purely about fixed
/// cost per wave.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Dispatch {
    /// The persistent scoring fabric
    /// ([`ScoringPool`](crate::compose::fabric::ScoringPool)): worker
    /// threads are spawned once, lazily on the first parallel wave,
    /// keep a long-lived [`Scratch`] arena each, and score chunks
    /// through [`ScoreBackend::score_batch_scratch`]. The default —
    /// at re-optimization frequencies the per-wave spawn/join and
    /// per-candidate buffer churn of the scoped path dominate cheap
    /// analytic scores.
    #[default]
    Pooled,
    /// Spawn a scoped thread pool per wave and score through the plain
    /// allocating [`ScoreBackend::score_batch`] — no long-lived state
    /// at all. Kept as the bit-identity oracle and as a fallback for
    /// environments where persistent threads are unwanted.
    SpawnPerWave,
}

/// A [`ScoreBackend`] combinator that fans each [`score_batch`] wave
/// across worker threads — the first scaling layer for wide candidate
/// searches over many-server pools, where the paper's response-time
/// tails make single-threaded wave scoring the planner's bottleneck.
///
/// [`score_batch`]: ScoreBackend::score_batch
///
/// The wave is split into chunks ([`ChunkPolicy`]), workers pull chunks
/// off a shared queue and score them through the inner backend, and the
/// results are reassembled **in input order**. Because [`ScoreBackend`]
/// scores candidates independently, the output is bit-identical to
/// running the inner backend serially — property-tested in
/// `tests/backend_equivalence.rs` and `tests/fabric_equivalence.rs`
/// across shard counts, chunkings and dispatch modes. Waves narrower
/// than [`ShardedBackend::min_wave`] (default
/// [`ShardedBackend::MIN_PARALLEL_WAVE`]; tune with
/// [`ShardedBackend::min_parallel_wave`]) and single-candidate
/// [`ScoreBackend::score`] calls are scored inline, so dispatch
/// cost is never paid where it cannot be amortized.
///
/// Two execution modes ([`Dispatch`]): the default [`Dispatch::Pooled`]
/// feeds waves to a lazily spawned persistent
/// [`ScoringPool`](crate::compose::fabric::ScoringPool) whose workers
/// reuse one [`Scratch`] arena each across all waves (dropped with the
/// backend); [`Dispatch::SpawnPerWave`] keeps the original scoped
/// per-wave pool. Fabric counters are observable through
/// [`ScoreBackend::fabric_stats`] in both modes.
///
/// The inner backend must be [`Sync`]: [`AnalyticBackend`],
/// [`EmpiricalBackend`] and
/// [`RuntimeBackend`](crate::runtime::scorer::RuntimeBackend) all are.
/// `RuntimeBackend` takes its scorer mutex once, briefly, per chunk to
/// read the active engine — native-engine chunks then score outside the
/// lock and overlap fully; XLA chunks score under it, so sharding
/// composes (correct scores) but waves serialize on the device.
///
/// Single-candidate scoring ([`ScoreBackend::score`]), diagnostics and
/// [`ScoreBackend::scoring_pool`] delegate straight to the inner
/// backend, so grid auto-sizing against a substituted scoring pool
/// behaves exactly as if the inner backend were injected directly.
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::fig6();
/// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
/// let sharded = ShardedBackend::new(&AnalyticBackend, 4);
/// let plan = Planner::new(&wf, &servers)
///     .backend(&sharded)
///     .plan(&ProposedPolicy::default())
///     .expect("feasible");
/// // bit-identical to the serial analytic path
/// let serial = Planner::new(&wf, &servers)
///     .plan(&ProposedPolicy::default())
///     .expect("feasible");
/// assert_eq!(plan.allocation, serial.allocation);
/// assert_eq!(plan.score.mean, serial.score.mean);
/// ```
pub struct ShardedBackend<'a> {
    inner: &'a (dyn ScoreBackend + Sync),
    shards: usize,
    chunking: ChunkPolicy,
    dispatch: Dispatch,
    min_wave: usize,
    pin_cores: Option<bool>,
    pool: OnceLock<ScoringPool>,
    waves_inline: AtomicUsize,
    waves_dispatched: AtomicUsize,
    chunks_dispatched: AtomicUsize,
    name: String,
}

impl<'a> ShardedBackend<'a> {
    /// Shard `inner` across `shards` worker threads (values `< 1` are
    /// treated as 1, i.e. serial). Builder-style: chain
    /// [`ShardedBackend::chunking`], [`ShardedBackend::dispatch`],
    /// [`ShardedBackend::min_parallel_wave`] or
    /// [`ShardedBackend::pin_cores`] to tune it.
    pub fn new(inner: &'a (dyn ScoreBackend + Sync), shards: usize) -> ShardedBackend<'a> {
        let shards = shards.max(1);
        ShardedBackend {
            inner,
            shards,
            chunking: ChunkPolicy::Even,
            dispatch: Dispatch::Pooled,
            min_wave: Self::MIN_PARALLEL_WAVE,
            pin_cores: None,
            pool: OnceLock::new(),
            waves_inline: AtomicUsize::new(0),
            waves_dispatched: AtomicUsize::new(0),
            chunks_dispatched: AtomicUsize::new(0),
            name: format!("sharded({})x{}", inner.name(), shards),
        }
    }

    /// Shard across one worker per available CPU
    /// ([`std::thread::available_parallelism`], 1 when unknown).
    pub fn per_cpu(inner: &'a (dyn ScoreBackend + Sync)) -> ShardedBackend<'a> {
        let shards = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(inner, shards)
    }

    /// Select the wave-splitting policy (default [`ChunkPolicy::Even`]).
    #[must_use]
    pub fn chunking(mut self, chunking: ChunkPolicy) -> ShardedBackend<'a> {
        self.chunking = chunking;
        self
    }

    /// Select the wave execution mode (default [`Dispatch::Pooled`]).
    #[must_use]
    pub fn dispatch(mut self, dispatch: Dispatch) -> ShardedBackend<'a> {
        self.dispatch = dispatch;
        self
    }

    /// Set the inline threshold: waves narrower than `n` are scored on
    /// the calling thread (default
    /// [`ShardedBackend::MIN_PARALLEL_WAVE`]; values `< 2` disable
    /// inlining short of single-candidate waves). Inline and parallel
    /// paths are bit-identical, so this is purely a scheduling knob.
    #[must_use]
    pub fn min_parallel_wave(mut self, n: usize) -> ShardedBackend<'a> {
        self.min_wave = n.max(2);
        self
    }

    /// Force core pinning on (`true`) or off (`false`) for pooled
    /// workers, overriding the `DCFLOW_PIN_CORES` environment knob
    /// (which is consulted when this builder is never called). Pinning
    /// only ever takes effect on Linux; see
    /// [`fabric`](crate::compose::fabric).
    #[must_use]
    pub fn pin_cores(mut self, pin: bool) -> ShardedBackend<'a> {
        self.pin_cores = Some(pin);
        self
    }

    /// Worker threads per wave.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Active wave-splitting policy.
    pub fn chunk_policy(&self) -> ChunkPolicy {
        self.chunking
    }

    /// Active wave execution mode.
    pub fn dispatch_mode(&self) -> Dispatch {
        self.dispatch
    }

    /// Active inline threshold (see
    /// [`ShardedBackend::min_parallel_wave`]).
    pub fn min_wave(&self) -> usize {
        self.min_wave
    }

    /// Default inline threshold: waves narrower than this are scored
    /// inline — dispatch (and, on the scoped path, thread spawn) costs
    /// that cheap analytic scores on a small wave cannot amortize
    /// (single-job refinement on small pools emits narrow O(slots²)
    /// rounds; the multi-job wave engine's cross-job candidate waves
    /// are wide and shard fully). Inline and parallel paths are
    /// bit-identical, so the threshold is purely a scheduling decision.
    /// Tune per backend with [`ShardedBackend::min_parallel_wave`].
    pub const MIN_PARALLEL_WAVE: usize = 8;

    /// Whether pooled workers should be pinned: the explicit builder
    /// choice when given, else the `DCFLOW_PIN_CORES` env knob.
    fn pin_workers(&self) -> bool {
        self.pin_cores.unwrap_or_else(|| {
            matches!(
                std::env::var("DCFLOW_PIN_CORES").as_deref(),
                Ok("1") | Ok("true")
            )
        })
    }

    /// Candidates per chunk for a wave of `wave_len`.
    fn chunk_len(&self, wave_len: usize) -> usize {
        match self.chunking {
            ChunkPolicy::Even => wave_len.div_ceil(self.shards).max(1),
            ChunkPolicy::Fixed(n) => n.max(1),
        }
    }
}

impl fmt::Debug for ShardedBackend<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ShardedBackend")
            .field("inner", &self.inner.name())
            .field("shards", &self.shards)
            .field("chunking", &self.chunking)
            .field("dispatch", &self.dispatch)
            .field("min_wave", &self.min_wave)
            .field("pool", &self.pool.get())
            .finish()
    }
}

impl ScoreBackend for ShardedBackend<'_> {
    fn name(&self) -> &str {
        &self.name
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        // one candidate cannot be split; no thread overhead
        self.inner.score(wf, alloc, servers, grid, model)
    }

    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        let chunk_len = self.chunk_len(allocs.len());
        let mut wave_span = crate::obs::span("backend.wave");
        if wave_span.is_recording() {
            wave_span.attr("wave", allocs.len());
        }
        if self.shards == 1 || allocs.len() <= chunk_len || allocs.len() < self.min_wave {
            wave_span.attr("inline", true);
            self.waves_inline.fetch_add(1, Ordering::Relaxed);
            return self.inner.score_batch(wf, allocs, servers, grid, model);
        }
        let chunks: Vec<&[Allocation]> = allocs.chunks(chunk_len).collect();
        let slots: Vec<Mutex<Vec<Score>>> =
            chunks.iter().map(|_| Mutex::new(Vec::new())).collect();
        self.waves_dispatched.fetch_add(1, Ordering::Relaxed);
        self.chunks_dispatched
            .fetch_add(chunks.len(), Ordering::Relaxed);
        if wave_span.is_recording() {
            wave_span.attr("inline", false);
            wave_span.attr("chunks", chunks.len());
            wave_span.attr(
                "dispatch",
                match self.dispatch {
                    Dispatch::Pooled => "pooled",
                    Dispatch::SpawnPerWave => "scoped",
                },
            );
        }
        // chunk spans run on worker threads: hand them the wave id (a
        // plain u64, freely Copy into the closures) so the cross-thread
        // parent edge survives; 0 (capture off) yields inert guards
        let wave_id = wave_span.id();
        match self.dispatch {
            Dispatch::Pooled => {
                let pool = self
                    .pool
                    .get_or_init(|| ScoringPool::with_pinning(self.shards, self.pin_workers()));
                pool.dispatch(chunks.len(), &|i, scratch: &mut Scratch| {
                    let mut chunk_span = crate::obs::span_under(wave_id, "backend.chunk");
                    if chunk_span.is_recording() {
                        chunk_span.attr("chunk", i);
                        chunk_span.attr("len", chunks[i].len());
                    }
                    let scored = self
                        .inner
                        .score_batch_scratch(wf, chunks[i], servers, grid, model, scratch);
                    *slots[i].lock().expect("shard result lock") = scored;
                });
            }
            Dispatch::SpawnPerWave => {
                let next = AtomicUsize::new(0);
                let workers = self.shards.min(chunks.len());
                std::thread::scope(|scope| {
                    for _ in 0..workers {
                        scope.spawn(|| loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            let Some(&chunk) = chunks.get(i) else { break };
                            let mut chunk_span =
                                crate::obs::span_under(wave_id, "backend.chunk");
                            if chunk_span.is_recording() {
                                chunk_span.attr("chunk", i);
                                chunk_span.attr("len", chunk.len());
                            }
                            let scored = self.inner.score_batch(wf, chunk, servers, grid, model);
                            *slots[i].lock().expect("shard result lock") = scored;
                        });
                    }
                });
            }
        }
        // reassemble in input order: slot i holds chunk i's scores
        slots
            .into_iter()
            .flat_map(|m| m.into_inner().expect("shard result lock"))
            .collect()
    }

    fn scoring_pool(&self, servers: &[Server]) -> Option<Vec<Server>> {
        // report the inner backend's effective pool so shared-grid
        // auto-sizing is unchanged by the sharding wrapper
        self.inner.scoring_pool(servers)
    }

    /// Always `Some`: the backend-level wave counters, merged with the
    /// pool's queue/scratch counters once the pool has spun up (the
    /// scoped mode, and a pooled backend that only ever saw inline
    /// waves, report zero pool counters).
    fn fabric_stats(&self) -> Option<FabricStats> {
        let mut st = self.pool.get().map(|p| p.stats()).unwrap_or_default();
        st.workers = self.shards;
        st.waves_inline = self.waves_inline.load(Ordering::Relaxed);
        st.waves_dispatched = self.waves_dispatched.load(Ordering::Relaxed);
        st.chunks_dispatched = self.chunks_dispatched.load(Ordering::Relaxed);
        Some(st)
    }
}

/// In-flight bookkeeping for one [`AsyncScoreBackend::score_stream`]
/// call: the bounded chunk queue between the enumerating producer and
/// the issuing consumers.
struct StreamQueue {
    /// Chunks awaiting dispatch, tagged with their input-order index.
    pending: VecDeque<(usize, Vec<Allocation>)>,
    /// The producer exhausted its candidate iterator.
    done: bool,
}

/// A [`ScoreBackend`] combinator that *pipelines* waves through the
/// persistent scoring fabric with a bounded number of chunks in flight
/// — the asynchronous scoring seam the live re-planning service
/// ([`crate::serve`]) plans through.
///
/// Where [`ShardedBackend`] submits a whole wave and blocks on one
/// fabric dispatch, this adapter runs up to
/// [`AsyncScoreBackend::in_flight`] issuer threads, each holding one
/// chunk open on the [`ScoringPool`](crate::compose::fabric::ScoringPool)
/// at a time (the pool is `Sync`; concurrent dispatches interleave on
/// per-wave latches). Two entry points share that machinery:
///
/// * [`ScoreBackend::score_batch`] — the wave is already materialized;
///   chunks are issued as issuer slots free up, so a slow chunk never
///   stalls the rest of the wave behind a single barrier;
/// * [`AsyncScoreBackend::score_stream`] — candidates arrive from an
///   **iterator still being enumerated**: full chunks enter a bounded
///   queue (capacity = the in-flight depth) while the caller keeps
///   producing, overlapping enumeration with scoring end to end.
///
/// Either way results are reassembled **in input order** and are
/// bit-identical to the inner backend run serially: candidates score
/// independently, chunk boundaries are a deterministic function of the
/// knobs, and thread scheduling only reorders *when* a slot is filled,
/// never *what* fills it. `tests/serve_equivalence.rs` property-tests
/// this across shard counts, in-flight depths and chunking policies.
///
/// Waves narrower than [`AsyncScoreBackend::min_parallel_wave`] (and
/// single-candidate [`ScoreBackend::score`] calls) are scored inline —
/// same rule, and same reasoning, as [`ShardedBackend`]. Diagnostics
/// ([`ScoreBackend::scoring_pool`], grid auto-sizing) delegate to the
/// inner backend, so wrapping never changes what gets scored.
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::fig6();
/// let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
/// let pipelined = AsyncScoreBackend::new(&AnalyticBackend, 2);
/// let plan = Planner::new(&wf, &servers)
///     .backend(&pipelined)
///     .plan(&ProposedPolicy::default())
///     .expect("feasible");
/// // bit-identical to the serial analytic path
/// let serial = Planner::new(&wf, &servers)
///     .plan(&ProposedPolicy::default())
///     .expect("feasible");
/// assert_eq!(plan.allocation, serial.allocation);
/// assert_eq!(plan.score.mean, serial.score.mean);
/// ```
pub struct AsyncScoreBackend<'a> {
    inner: &'a (dyn ScoreBackend + Sync),
    shards: usize,
    in_flight: usize,
    chunking: ChunkPolicy,
    min_wave: usize,
    pin_cores: Option<bool>,
    pool: OnceLock<ScoringPool>,
    waves_inline: AtomicUsize,
    waves_pipelined: AtomicUsize,
    chunks_pipelined: AtomicUsize,
    peak_in_flight: AtomicUsize,
    name: String,
}

impl<'a> AsyncScoreBackend<'a> {
    /// Default bound on chunks concurrently held open on the fabric.
    /// Deep enough to hide one straggling chunk behind its successors,
    /// shallow enough that a re-plan never floods the pool queue.
    pub const DEFAULT_IN_FLIGHT: usize = 4;

    /// Pipeline `inner` across `shards` fabric workers (values `< 1`
    /// are treated as 1) with the default in-flight depth.
    /// Builder-style: chain [`AsyncScoreBackend::in_flight`],
    /// [`AsyncScoreBackend::chunking`],
    /// [`AsyncScoreBackend::min_parallel_wave`] or
    /// [`AsyncScoreBackend::pin_cores`] to tune it.
    pub fn new(inner: &'a (dyn ScoreBackend + Sync), shards: usize) -> AsyncScoreBackend<'a> {
        let shards = shards.max(1);
        AsyncScoreBackend {
            inner,
            shards,
            in_flight: Self::DEFAULT_IN_FLIGHT,
            chunking: ChunkPolicy::Even,
            min_wave: ShardedBackend::MIN_PARALLEL_WAVE,
            pin_cores: None,
            pool: OnceLock::new(),
            waves_inline: AtomicUsize::new(0),
            waves_pipelined: AtomicUsize::new(0),
            chunks_pipelined: AtomicUsize::new(0),
            peak_in_flight: AtomicUsize::new(0),
            name: format!("async({})x{}", inner.name(), shards),
        }
    }

    /// Bound the number of chunks concurrently in flight (queued or
    /// scoring; values `< 1` are treated as 1 — fully serial issue,
    /// still bit-identical).
    #[must_use]
    pub fn in_flight(mut self, depth: usize) -> AsyncScoreBackend<'a> {
        self.in_flight = depth.max(1);
        self
    }

    /// Select the wave-splitting policy (default [`ChunkPolicy::Even`];
    /// [`ChunkPolicy::Fixed`] also sets the stream granule of
    /// [`AsyncScoreBackend::score_stream`]).
    #[must_use]
    pub fn chunking(mut self, chunking: ChunkPolicy) -> AsyncScoreBackend<'a> {
        self.chunking = chunking;
        self
    }

    /// Set the inline threshold: materialized waves narrower than `n`
    /// are scored on the calling thread (default
    /// [`ShardedBackend::MIN_PARALLEL_WAVE`]; values `< 2` are clamped).
    /// Inline and pipelined paths are bit-identical, so this is purely
    /// a scheduling knob.
    #[must_use]
    pub fn min_parallel_wave(mut self, n: usize) -> AsyncScoreBackend<'a> {
        self.min_wave = n.max(2);
        self
    }

    /// Force core pinning on (`true`) or off (`false`) for the fabric
    /// workers, overriding the `DCFLOW_PIN_CORES` environment knob.
    #[must_use]
    pub fn pin_cores(mut self, pin: bool) -> AsyncScoreBackend<'a> {
        self.pin_cores = Some(pin);
        self
    }

    /// Fabric worker threads.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Active in-flight depth bound.
    pub fn in_flight_depth(&self) -> usize {
        self.in_flight
    }

    /// Active wave-splitting policy.
    pub fn chunk_policy(&self) -> ChunkPolicy {
        self.chunking
    }

    /// Active inline threshold.
    pub fn min_wave(&self) -> usize {
        self.min_wave
    }

    /// High-water mark of chunks concurrently held open on the fabric
    /// over this backend's lifetime — never exceeds
    /// [`AsyncScoreBackend::in_flight_depth`] (pinned in
    /// `tests/serve_equivalence.rs`).
    pub fn peak_in_flight(&self) -> usize {
        self.peak_in_flight.load(Ordering::Relaxed)
    }

    /// Score candidates from an iterator **while it is still being
    /// enumerated**: every time a full chunk accumulates it enters a
    /// bounded queue (capacity = the in-flight depth) consumed by the
    /// issuer threads, so enumeration and fabric scoring overlap. The
    /// returned scores are in enumeration order and bit-identical to
    /// `inner.score_batch` over the collected candidates.
    ///
    /// The enumerating (calling) thread blocks only when the queue is
    /// full — the backpressure that keeps a fast producer from flooding
    /// the fabric.
    pub fn score_stream<I>(
        &self,
        wf: &Workflow,
        candidates: I,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score>
    where
        I: IntoIterator<Item = Allocation>,
    {
        let granule = match self.chunking {
            ChunkPolicy::Even => self.min_wave,
            ChunkPolicy::Fixed(n) => n.max(1),
        };
        let mut wave_span = crate::obs::span("backend.wave");
        if wave_span.is_recording() {
            wave_span.attr("stream", true);
            wave_span.attr("granule", granule);
        }
        let wave_id = wave_span.id();
        let pool = self.pool();
        let queue = Mutex::new(StreamQueue {
            pending: VecDeque::new(),
            done: false,
        });
        let space = Condvar::new(); // producer waits: queue below capacity
        let ready = Condvar::new(); // issuers wait: chunk available or done
        let slots: Mutex<Vec<Option<Vec<Score>>>> = Mutex::new(Vec::new());
        let live = AtomicUsize::new(0);
        let issued = AtomicUsize::new(0);
        self.waves_pipelined.fetch_add(1, Ordering::Relaxed);
        std::thread::scope(|scope| {
            for _ in 0..self.in_flight {
                scope.spawn(|| loop {
                    let (idx, chunk) = {
                        let mut q = queue.lock().expect("stream queue lock");
                        while q.pending.is_empty() && !q.done {
                            q = ready.wait(q).expect("stream queue lock");
                        }
                        let Some(item) = q.pending.pop_front() else {
                            break; // empty and done: drain complete
                        };
                        space.notify_one();
                        item
                    };
                    let depth = live.fetch_add(1, Ordering::Relaxed) + 1;
                    self.peak_in_flight.fetch_max(depth, Ordering::Relaxed);
                    let scored = self.issue_chunk(wave_id, wf, idx, &chunk, servers, grid, model, pool);
                    live.fetch_sub(1, Ordering::Relaxed);
                    issued.fetch_add(1, Ordering::Relaxed);
                    slots.lock().expect("stream slot lock")[idx] = Some(scored);
                });
            }
            // the producer runs on the calling thread: enumeration
            // proceeds while earlier chunks are already on the fabric
            let mut buf: Vec<Allocation> = Vec::with_capacity(granule);
            let mut next_idx = 0usize;
            for cand in candidates {
                buf.push(cand);
                if buf.len() == granule {
                    self.push_chunk(&queue, &space, &ready, &slots, next_idx, std::mem::take(&mut buf));
                    next_idx += 1;
                    buf.reserve(granule);
                }
            }
            if !buf.is_empty() {
                self.push_chunk(&queue, &space, &ready, &slots, next_idx, buf);
            }
            let mut q = queue.lock().expect("stream queue lock");
            q.done = true;
            ready.notify_all();
        });
        self.chunks_pipelined
            .fetch_add(issued.load(Ordering::Relaxed), Ordering::Relaxed);
        slots
            .into_inner()
            .expect("stream slot lock")
            .into_iter()
            .flat_map(|s| s.expect("every stream chunk scored"))
            .collect()
    }

    /// Enqueue one chunk for the issuers, blocking while the queue is
    /// at capacity (the stream's backpressure point), and grow the
    /// ordered result slots to cover its index.
    fn push_chunk(
        &self,
        queue: &Mutex<StreamQueue>,
        space: &Condvar,
        ready: &Condvar,
        slots: &Mutex<Vec<Option<Vec<Score>>>>,
        idx: usize,
        chunk: Vec<Allocation>,
    ) {
        slots.lock().expect("stream slot lock").push(None);
        let mut q = queue.lock().expect("stream queue lock");
        while q.pending.len() >= self.in_flight {
            q = space.wait(q).expect("stream queue lock");
        }
        q.pending.push_back((idx, chunk));
        ready.notify_one();
    }

    /// Score one chunk through the fabric (one single-chunk dispatch —
    /// concurrent issuers interleave on the pool's per-wave latches)
    /// and hand back its scores.
    #[allow(clippy::too_many_arguments)]
    fn issue_chunk(
        &self,
        wave_id: u64,
        wf: &Workflow,
        idx: usize,
        chunk: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
        pool: &ScoringPool,
    ) -> Vec<Score> {
        let out: Mutex<Vec<Score>> = Mutex::new(Vec::new());
        pool.dispatch(1, &|_, scratch: &mut Scratch| {
            let mut chunk_span = crate::obs::span_under(wave_id, "backend.chunk");
            if chunk_span.is_recording() {
                chunk_span.attr("chunk", idx);
                chunk_span.attr("len", chunk.len());
            }
            let scored = self
                .inner
                .score_batch_scratch(wf, chunk, servers, grid, model, scratch);
            *out.lock().expect("async result lock") = scored;
        });
        out.into_inner().expect("async result lock")
    }

    /// The lazily spun-up fabric.
    fn pool(&self) -> &ScoringPool {
        self.pool
            .get_or_init(|| ScoringPool::with_pinning(self.shards, self.pin_workers()))
    }

    /// Whether fabric workers should be pinned: the explicit builder
    /// choice when given, else the `DCFLOW_PIN_CORES` env knob.
    fn pin_workers(&self) -> bool {
        self.pin_cores.unwrap_or_else(|| {
            matches!(
                std::env::var("DCFLOW_PIN_CORES").as_deref(),
                Ok("1") | Ok("true")
            )
        })
    }

    /// Candidates per chunk for a materialized wave of `wave_len`
    /// (same policy arithmetic as [`ShardedBackend`]).
    fn chunk_len(&self, wave_len: usize) -> usize {
        match self.chunking {
            ChunkPolicy::Even => wave_len.div_ceil(self.shards).max(1),
            ChunkPolicy::Fixed(n) => n.max(1),
        }
    }
}

impl fmt::Debug for AsyncScoreBackend<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AsyncScoreBackend")
            .field("inner", &self.inner.name())
            .field("shards", &self.shards)
            .field("in_flight", &self.in_flight)
            .field("chunking", &self.chunking)
            .field("min_wave", &self.min_wave)
            .field("pool", &self.pool.get())
            .finish()
    }
}

impl ScoreBackend for AsyncScoreBackend<'_> {
    fn name(&self) -> &str {
        &self.name
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        // one candidate cannot be pipelined; no thread overhead
        self.inner.score(wf, alloc, servers, grid, model)
    }

    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        let chunk_len = self.chunk_len(allocs.len());
        let mut wave_span = crate::obs::span("backend.wave");
        if wave_span.is_recording() {
            wave_span.attr("wave", allocs.len());
        }
        if self.shards == 1 || allocs.len() <= chunk_len || allocs.len() < self.min_wave {
            wave_span.attr("inline", true);
            self.waves_inline.fetch_add(1, Ordering::Relaxed);
            return self.inner.score_batch(wf, allocs, servers, grid, model);
        }
        let chunks: Vec<&[Allocation]> = allocs.chunks(chunk_len).collect();
        let slots: Vec<Mutex<Vec<Score>>> =
            chunks.iter().map(|_| Mutex::new(Vec::new())).collect();
        self.waves_pipelined.fetch_add(1, Ordering::Relaxed);
        self.chunks_pipelined
            .fetch_add(chunks.len(), Ordering::Relaxed);
        if wave_span.is_recording() {
            wave_span.attr("inline", false);
            wave_span.attr("chunks", chunks.len());
            wave_span.attr("in_flight", self.in_flight);
        }
        let wave_id = wave_span.id();
        let pool = self.pool();
        let next = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let issuers = self.in_flight.min(chunks.len());
        std::thread::scope(|scope| {
            for _ in 0..issuers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(&chunk) = chunks.get(i) else { break };
                    let depth = live.fetch_add(1, Ordering::Relaxed) + 1;
                    self.peak_in_flight.fetch_max(depth, Ordering::Relaxed);
                    let scored =
                        self.issue_chunk(wave_id, wf, i, chunk, servers, grid, model, pool);
                    live.fetch_sub(1, Ordering::Relaxed);
                    *slots[i].lock().expect("async result lock") = scored;
                });
            }
        });
        // reassemble in input order: slot i holds chunk i's scores
        slots
            .into_iter()
            .flat_map(|m| m.into_inner().expect("async result lock"))
            .collect()
    }

    fn scoring_pool(&self, servers: &[Server]) -> Option<Vec<Server>> {
        // report the inner backend's effective pool so shared-grid
        // auto-sizing is unchanged by the pipelining wrapper
        self.inner.scoring_pool(servers)
    }

    /// Always `Some`: backend-level wave counters (pipelined waves
    /// under `waves_dispatched`, issued chunks under
    /// `chunks_dispatched`) merged with the pool's queue/scratch
    /// counters once the fabric has spun up.
    fn fabric_stats(&self) -> Option<FabricStats> {
        let mut st = self.pool.get().map(|p| p.stats()).unwrap_or_default();
        st.workers = self.shards;
        st.waves_inline = self.waves_inline.load(Ordering::Relaxed);
        st.waves_dispatched = self.waves_pipelined.load(Ordering::Relaxed);
        st.chunks_dispatched = self.chunks_pipelined.load(Ordering::Relaxed);
        Some(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{Planner, SdccPolicy};
    use crate::sched::allocate_with;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn analytic_backend_is_the_free_function_bit_for_bit() {
        // the satellite property: AnalyticBackend through Planner must be
        // bit-identical to a direct score_allocation_with call
        prop::run("AnalyticBackend == score_allocation_with", 25, |g| {
            let n = g.usize_in(2, 5);
            let wf = if g.bool(0.5) {
                Workflow::tandem(n, g.f64_in(0.3, 1.2))
            } else {
                Workflow::forkjoin(n, g.f64_in(0.3, 1.2))
            };
            let rates: Vec<f64> = (0..wf.slots()).map(|_| g.f64_in(3.0, 20.0)).collect();
            let servers = Server::pool_exponential(&rates);
            let Ok(alloc) = allocate_with(&wf, &servers, ResponseModel::Mm1) else {
                return; // infeasible draw
            };
            let grid = GridSpec::auto_response(&alloc, &servers, ResponseModel::Mm1);
            let direct = score_allocation_with(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);

            // via the trait object
            let backend: &dyn ScoreBackend = &AnalyticBackend;
            let via_trait = backend.score(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);
            assert_eq!(direct.mean, via_trait.mean);
            assert_eq!(direct.var, via_trait.var);
            assert_eq!(direct.p99, via_trait.p99);
            assert_eq!(direct.pdf, via_trait.pdf);

            // via the full Planner surface (injected backend + pinned grid)
            let via_planner = Planner::new(&wf, &servers)
                .backend(&AnalyticBackend)
                .grid(grid)
                .score(&alloc);
            assert_eq!(direct.mean, via_planner.mean);
            assert_eq!(direct.var, via_planner.var);
            assert_eq!(direct.p99, via_planner.p99);

            // and score_batch defaults to the same per-item scores
            let batch = backend.score_batch(
                &wf,
                std::slice::from_ref(&alloc),
                &servers,
                &grid,
                ResponseModel::Mm1,
            );
            assert_eq!(batch.len(), 1);
            assert_eq!(batch[0].mean, direct.mean);
        });
    }

    #[test]
    fn empty_empirical_backend_matches_analytic() {
        let (wf, servers) = fig6();
        let alloc = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto_response(&alloc, &servers, ResponseModel::Mm1);
        let a = AnalyticBackend.score(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);
        let e = EmpiricalBackend::new().score(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);
        assert_eq!(a.mean, e.mean);
        assert_eq!(a.p99, e.p99);
    }

    #[test]
    fn empirical_backend_tracks_measured_laws() {
        // believed pool says all servers are Exp(2); measurements reveal
        // Exp(9..4). Scoring through the empirical backend must land close
        // to the truth-pool analytic score.
        let (wf, truth) = fig6();
        let believed = Server::pool_exponential(&[2.0; 6]);
        let mut rng = Rng::new(11);
        let mut backend = EmpiricalBackend::new();
        for (sid, s) in truth.iter().enumerate() {
            let samples: Vec<f64> = (0..4000).map(|_| s.dist.sample(&mut rng)).collect();
            backend = backend.with_samples(sid, &samples);
        }
        assert_eq!(backend.measured_servers(), 6);
        let alloc = allocate_with(&wf, &truth, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto_response(&alloc, &truth, ResponseModel::Mm1);
        let want = AnalyticBackend.score(&wf, &alloc, &truth, &grid, ResponseModel::Mm1);
        let got = backend.score(&wf, &alloc, &believed, &grid, ResponseModel::Mm1);
        assert!(got.is_stable());
        assert!(
            (got.mean - want.mean).abs() < 0.10 * want.mean,
            "empirical {} vs truth {}",
            got.mean,
            want.mean
        );
    }

    #[test]
    fn auto_grid_covers_measured_tails() {
        // believed laws are short-tailed Exp(10); the measured law of
        // server 0 straggles with a ~25x longer tail. The planner's auto
        // grid must be sized against the scoring (measured) laws, so the
        // empirical score keeps its probability mass on the grid.
        let wf = Workflow::tandem(2, 1.0);
        let believed = Server::pool_exponential(&[10.0, 9.0]);
        let straggler = ServiceDist::straggler(10.0, 0.4, 0.08, 0.01);
        let mut rng = Rng::new(7);
        let samples: Vec<f64> = (0..6000).map(|_| straggler.sample(&mut rng)).collect();
        let backend = EmpiricalBackend::new().with_samples(0, &samples);
        assert!(backend.scoring_pool(&believed).is_some());
        let plan = Planner::new(&wf, &believed)
            .backend(&backend)
            .plan(&SdccPolicy)
            .expect("feasible");
        assert!(plan.score.is_stable());
        assert!(
            plan.score.mass > 0.95,
            "measured tail truncated: mass {}",
            plan.score.mass
        );
        // and the believed-law-only grid really would have truncated it
        let believed_grid = Planner::new(&wf, &believed)
            .plan(&SdccPolicy)
            .unwrap()
            .diagnostics
            .grid;
        assert!(
            plan.diagnostics.grid.t_max() > 2.0 * believed_grid.t_max(),
            "scoring-pool grid {:?} should be much wider than believed grid {:?}",
            plan.diagnostics.grid,
            believed_grid
        );
    }

    #[test]
    fn backend_names_are_stable() {
        assert_eq!(AnalyticBackend.name(), "analytic");
        assert_eq!(EmpiricalBackend::new().name(), "empirical");
        assert_eq!(ShardedBackend::new(&AnalyticBackend, 4).name(), "sharded(analytic)x4");
        assert_eq!(AsyncScoreBackend::new(&AnalyticBackend, 4).name(), "async(analytic)x4");
    }

    #[test]
    fn sharded_batch_preserves_order_and_bits() {
        // a wave of distinct candidates: the sharded scores must be the
        // serial scores in the same positions, bit for bit, whatever the
        // shard count or chunking
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let mut waves: Vec<Allocation> = Vec::new();
        let mut assign: Vec<usize> = (0..6).collect();
        for _ in 0..6 {
            assign.rotate_left(1);
            if let Ok(a) = crate::sched::schedule_rates(&wf, assign.clone(), &servers, model) {
                waves.push(a);
            }
            for i in 0..5 {
                let mut swapped = assign.clone();
                swapped.swap(i, i + 1);
                if let Ok(a) = crate::sched::schedule_rates(&wf, swapped, &servers, model) {
                    waves.push(a);
                }
            }
        }
        // wide enough that every shard count below really spawns workers
        assert!(waves.len() >= ShardedBackend::MIN_PARALLEL_WAVE);
        let grid = GridSpec::auto_response(&waves[0], &servers, model);
        let serial = AnalyticBackend.score_batch(&wf, &waves, &servers, &grid, model);
        for shards in [1usize, 2, 3, 8, 17] {
            for chunking in [ChunkPolicy::Even, ChunkPolicy::Fixed(1), ChunkPolicy::Fixed(4)] {
                let sharded = ShardedBackend::new(&AnalyticBackend, shards).chunking(chunking);
                let got = sharded.score_batch(&wf, &waves, &servers, &grid, model);
                assert_eq!(got.len(), serial.len());
                for (g, s) in got.iter().zip(serial.iter()) {
                    assert_eq!(g.mean, s.mean, "{shards} shards / {chunking:?}");
                    assert_eq!(g.var, s.var);
                    assert_eq!(g.p99, s.p99);
                    assert_eq!(g.mass, s.mass);
                    assert_eq!(g.pdf, s.pdf);
                }
            }
        }
    }

    #[test]
    fn sharded_delegates_scoring_pool() {
        // grid auto-sizing must see the inner backend's substituted pool
        let (_, servers) = fig6();
        let straggler = ServiceDist::straggler(10.0, 0.4, 0.08, 0.01);
        let mut rng = crate::util::rng::Rng::new(3);
        let samples: Vec<f64> = (0..4000).map(|_| straggler.sample(&mut rng)).collect();
        let inner = EmpiricalBackend::new().with_samples(0, &samples);
        let sharded = ShardedBackend::new(&inner, 4);
        let via_inner = inner.scoring_pool(&servers).expect("measured pool");
        let via_sharded = sharded.scoring_pool(&servers).expect("delegated pool");
        assert_eq!(via_inner.len(), via_sharded.len());
        for (a, b) in via_inner.iter().zip(via_sharded.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.dist.mean(), b.dist.mean());
        }
        // and a shard count below 1 degrades to serial, not a panic
        assert_eq!(ShardedBackend::new(&AnalyticBackend, 0).shards(), 1);
    }

    #[test]
    fn pooled_dispatch_matches_scoped_and_serial() {
        // quick in-module check; the full matrix lives in
        // tests/fabric_equivalence.rs
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let mut assign: Vec<usize> = (0..6).collect();
        let mut wave = Vec::new();
        for _ in 0..12 {
            assign.rotate_left(1);
            if let Ok(a) = crate::sched::schedule_rates(&wf, assign.clone(), &servers, model) {
                wave.push(a);
            }
        }
        assert!(wave.len() >= ShardedBackend::MIN_PARALLEL_WAVE);
        let grid = GridSpec::auto_response(&wave[0], &servers, model);
        let serial = AnalyticBackend.score_batch(&wf, &wave, &servers, &grid, model);
        let pooled = ShardedBackend::new(&AnalyticBackend, 3);
        assert_eq!(pooled.dispatch_mode(), Dispatch::Pooled);
        let scoped = ShardedBackend::new(&AnalyticBackend, 3).dispatch(Dispatch::SpawnPerWave);
        for backend in [&pooled, &scoped] {
            let got = backend.score_batch(&wf, &wave, &servers, &grid, model);
            assert_eq!(got.len(), serial.len());
            for (g, s) in got.iter().zip(serial.iter()) {
                assert_eq!(g.mean.to_bits(), s.mean.to_bits());
                assert_eq!(g.var.to_bits(), s.var.to_bits());
                assert_eq!(g.p99.to_bits(), s.p99.to_bits());
                assert_eq!(g.pdf, s.pdf);
            }
        }
        // the pooled backend spun its fabric up and saw the wave
        let st = pooled.fabric_stats().expect("sharded always reports");
        assert_eq!(st.workers, 3);
        assert_eq!(st.waves_dispatched, 1);
        assert!(st.chunks_dispatched >= 2);
        assert!(st.max_queue_depth >= 1);
        // the scoped backend reports wave counters but no pool counters
        let st = scoped.fabric_stats().expect("sharded always reports");
        assert_eq!(st.waves_dispatched, 1);
        assert_eq!(st.max_queue_depth, 0);
        assert_eq!(st.scratch_allocs, 0);
    }

    #[test]
    fn min_parallel_wave_keeps_small_waves_inline() {
        // the builder knob: waves below the threshold stay on the
        // caller thread in both dispatch modes (observable through the
        // inline/dispatched counters), and raising the threshold
        // inlines waves the default would have fanned out
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let mut assign: Vec<usize> = (0..6).collect();
        let mut wave = Vec::new();
        for _ in 0..12 {
            assign.rotate_left(1);
            if let Ok(a) = crate::sched::schedule_rates(&wf, assign.clone(), &servers, model) {
                wave.push(a);
            }
        }
        let grid = GridSpec::auto_response(&wave[0], &servers, model);
        let small = &wave[..ShardedBackend::MIN_PARALLEL_WAVE - 1];
        for dispatch in [Dispatch::Pooled, Dispatch::SpawnPerWave] {
            let b = ShardedBackend::new(&AnalyticBackend, 3).dispatch(dispatch);
            assert_eq!(b.min_wave(), ShardedBackend::MIN_PARALLEL_WAVE);
            b.score_batch(&wf, small, &servers, &grid, model);
            let st = b.fabric_stats().unwrap();
            assert_eq!(st.waves_inline, 1, "{dispatch:?}");
            assert_eq!(st.waves_dispatched, 0, "{dispatch:?}");

            // raised threshold: the full wave stays inline too
            let b = ShardedBackend::new(&AnalyticBackend, 3)
                .dispatch(dispatch)
                .min_parallel_wave(wave.len() + 1);
            b.score_batch(&wf, &wave, &servers, &grid, model);
            assert_eq!(b.fabric_stats().unwrap().waves_inline, 1);

            // lowered threshold: a formerly-inline wave now fans out
            let b = ShardedBackend::new(&AnalyticBackend, 3)
                .dispatch(dispatch)
                .min_parallel_wave(2)
                .chunking(ChunkPolicy::Fixed(1));
            b.score_batch(&wf, small, &servers, &grid, model);
            let st = b.fabric_stats().unwrap();
            assert_eq!(st.waves_inline, 0, "{dispatch:?}");
            assert_eq!(st.waves_dispatched, 1, "{dispatch:?}");
        }
    }

    /// A ~36-candidate wave (wide enough that every knob combination
    /// below really pipelines) plus its serial oracle scores.
    fn pipeline_wave() -> (Workflow, Vec<Server>, Vec<Allocation>, GridSpec, Vec<Score>) {
        let (wf, servers) = fig6();
        let model = ResponseModel::Mm1;
        let mut wave: Vec<Allocation> = Vec::new();
        let mut assign: Vec<usize> = (0..6).collect();
        for _ in 0..6 {
            assign.rotate_left(1);
            if let Ok(a) = crate::sched::schedule_rates(&wf, assign.clone(), &servers, model) {
                wave.push(a);
            }
            for i in 0..5 {
                let mut swapped = assign.clone();
                swapped.swap(i, i + 1);
                if let Ok(a) = crate::sched::schedule_rates(&wf, swapped, &servers, model) {
                    wave.push(a);
                }
            }
        }
        assert!(wave.len() >= 2 * ShardedBackend::MIN_PARALLEL_WAVE);
        let grid = GridSpec::auto_response(&wave[0], &servers, model);
        let serial = AnalyticBackend.score_batch(&wf, &wave, &servers, &grid, model);
        (wf, servers, wave, grid, serial)
    }

    #[test]
    fn async_batch_matches_serial_bits() {
        // quick in-module check; the full knob matrix lives in
        // tests/serve_equivalence.rs
        let (wf, servers, wave, grid, serial) = pipeline_wave();
        let model = ResponseModel::Mm1;
        let b = AsyncScoreBackend::new(&AnalyticBackend, 3)
            .in_flight(2)
            .chunking(ChunkPolicy::Fixed(4));
        let got = b.score_batch(&wf, &wave, &servers, &grid, model);
        assert_eq!(got.len(), serial.len());
        for (g, s) in got.iter().zip(serial.iter()) {
            assert_eq!(g.mean.to_bits(), s.mean.to_bits());
            assert_eq!(g.var.to_bits(), s.var.to_bits());
            assert_eq!(g.p99.to_bits(), s.p99.to_bits());
            assert_eq!(g.pdf, s.pdf);
        }
        // the fabric really saw the wave, within the in-flight bound
        let st = b.fabric_stats().expect("async always reports");
        assert_eq!(st.workers, 3);
        assert_eq!(st.waves_dispatched, 1);
        assert!(st.chunks_dispatched >= 2);
        assert!(b.peak_in_flight() >= 1);
        assert!(b.peak_in_flight() <= 2, "peak {}", b.peak_in_flight());
    }

    #[test]
    fn async_stream_overlaps_enumeration_bit_identically() {
        // candidates delivered one at a time from a live iterator:
        // order and bits must match the serial batch over the same
        // enumeration, whatever the granule
        let (wf, servers, wave, grid, serial) = pipeline_wave();
        let model = ResponseModel::Mm1;
        for chunking in [ChunkPolicy::Even, ChunkPolicy::Fixed(1), ChunkPolicy::Fixed(5)] {
            let b = AsyncScoreBackend::new(&AnalyticBackend, 2)
                .in_flight(3)
                .chunking(chunking);
            let got = b.score_stream(&wf, wave.iter().cloned(), &servers, &grid, model);
            assert_eq!(got.len(), serial.len(), "{chunking:?}");
            for (g, s) in got.iter().zip(serial.iter()) {
                assert_eq!(g.mean.to_bits(), s.mean.to_bits(), "{chunking:?}");
                assert_eq!(g.pdf, s.pdf);
            }
            assert!(b.peak_in_flight() <= 3);
        }
        // an empty stream is fine and yields an empty wave
        let b = AsyncScoreBackend::new(&AnalyticBackend, 2);
        let got = b.score_stream(&wf, std::iter::empty(), &servers, &grid, model);
        assert!(got.is_empty());
    }

    #[test]
    fn async_inline_and_clamp_rules_match_sharded() {
        let (wf, servers, wave, grid, _) = pipeline_wave();
        let model = ResponseModel::Mm1;
        // narrow waves stay inline
        let b = AsyncScoreBackend::new(&AnalyticBackend, 3);
        let small = &wave[..ShardedBackend::MIN_PARALLEL_WAVE - 1];
        b.score_batch(&wf, small, &servers, &grid, model);
        let st = b.fabric_stats().unwrap();
        assert_eq!(st.waves_inline, 1);
        assert_eq!(st.waves_dispatched, 0);
        // degenerate knobs clamp instead of panicking
        assert_eq!(AsyncScoreBackend::new(&AnalyticBackend, 0).shards(), 1);
        assert_eq!(
            AsyncScoreBackend::new(&AnalyticBackend, 2).in_flight(0).in_flight_depth(),
            1
        );
    }

    #[test]
    fn async_handles_unstable_candidates() {
        // unstable rows keep their position and their infinite sentinel
        // through the pipelined path
        let wf = Workflow::tandem(1, 5.0);
        let servers = Server::pool_exponential(&[20.0, 2.0]); // server 1 overloads at λ=5
        let grid = GridSpec::new(0.01, 1024);
        let ok_alloc = Allocation::new(vec![0], vec![5.0], &wf, 2).unwrap();
        let bad = Allocation::new(vec![1], vec![5.0], &wf, 2).unwrap();
        let wave: Vec<Allocation> = (0..12)
            .map(|i| if i % 3 == 0 { ok_alloc.clone() } else { bad.clone() })
            .collect();
        let b = AsyncScoreBackend::new(&AnalyticBackend, 3).chunking(ChunkPolicy::Fixed(2));
        let got = b.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
        assert_eq!(got.len(), 12);
        for (i, s) in got.iter().enumerate() {
            if i % 3 == 0 {
                assert!(s.is_stable(), "row {i}");
            } else {
                assert!(!s.is_stable(), "row {i}");
                assert_eq!(s.mean, f64::INFINITY);
            }
        }
    }

    #[test]
    fn sharded_handles_unstable_candidates() {
        // unstable rows keep their position and their infinite sentinel,
        // on a wave wide enough to actually shard
        let wf = Workflow::tandem(1, 5.0);
        let servers = Server::pool_exponential(&[20.0, 2.0]); // server 1 overloads at λ=5
        let grid = GridSpec::new(0.01, 1024);
        let ok_alloc = Allocation::new(vec![0], vec![5.0], &wf, 2).unwrap();
        let bad = Allocation::new(vec![1], vec![5.0], &wf, 2).unwrap();
        let wave: Vec<Allocation> = (0..12)
            .map(|i| if i % 3 == 0 { ok_alloc.clone() } else { bad.clone() })
            .collect();
        let sharded = ShardedBackend::new(&AnalyticBackend, 3);
        let got = sharded.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
        assert_eq!(got.len(), 12);
        for (i, s) in got.iter().enumerate() {
            if i % 3 == 0 {
                assert!(s.is_stable(), "row {i}");
            } else {
                assert!(!s.is_stable(), "row {i}");
                assert_eq!(s.mean, f64::INFINITY);
                assert_eq!(s.mass, 0.0);
            }
        }
    }
}
