//! The pluggable scoring seam: one trait every predictor sits behind.
//!
//! The paper's allocation algorithms repeatedly query a response-time
//! *predictor* (mean/variance/p99 of the end-to-end law under a
//! candidate allocation). [`ScoreBackend`] abstracts that predictor so
//! the [`Planner`](crate::plan::Planner), the refinement and exhaustive
//! search engines, and the multi-job partitioner all evaluate against
//! an injected backend instead of a hard-wired free function:
//!
//! * [`AnalyticBackend`] — the native composition engine
//!   ([`score_allocation_with`]), exact and allocation-shaped; the
//!   default everywhere;
//! * [`RuntimeBackend`](crate::runtime::scorer::RuntimeBackend) — the
//!   batched PJRT/AOT scorer folded in as just another implementation
//!   (lives in [`crate::runtime::scorer`]);
//! * [`EmpiricalBackend`] — scores against *measured* laws fitted from
//!   [`crate::dist::empirical`] samples instead of the believed pool,
//!   the "swap the analytic model for data" move of the runtime-variation
//!   literature.
//!
//! Custom predictors (sharded scorers, learned models, remote services)
//! implement the same trait and plug into
//! [`Planner::backend`](crate::plan::Planner::backend).
//!
//! ```
//! use dcflow::prelude::*;
//!
//! let wf = Workflow::fig6();
//! let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
//!
//! // The default planner scores through AnalyticBackend; injecting it
//! // explicitly is identical, bit for bit.
//! let backend = AnalyticBackend;
//! let plan = Planner::new(&wf, &servers)
//!     .backend(&backend)
//!     .plan(&SdccPolicy)
//!     .expect("fig6 is feasible");
//! assert!(plan.score.mean > 0.0);
//! ```

use std::borrow::Cow;

use crate::compose::grid::GridSpec;
use crate::compose::score::{score_allocation_with, Score};
use crate::dist::empirical::Empirical;
use crate::dist::fit::select_family;
use crate::dist::ServiceDist;
use crate::flow::Workflow;
use crate::sched::response::ResponseModel;
use crate::sched::server::Server;
use crate::sched::Allocation;

/// A response-time predictor: maps (workflow, allocation, pool, grid,
/// queueing model) to a [`Score`]. Implementations must return
/// [`Score::unstable`]-style infinite scores (not panic) when a queue
/// in the allocation diverges, so search loops can skip the candidate.
///
/// Methods take `&self`; implementations that mutate internal state
/// (artifact caches, device handles) use interior mutability — see
/// [`RuntimeBackend`](crate::runtime::scorer::RuntimeBackend).
pub trait ScoreBackend {
    /// Short stable name for diagnostics and CSV rows.
    fn name(&self) -> &str;

    /// Score one allocation on `grid` under `model`.
    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score;

    /// Score a wave of candidate allocations (the optimizer's inner
    /// loop). The default maps [`ScoreBackend::score`] over the slice;
    /// batched implementations override this with one fused evaluation.
    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        allocs
            .iter()
            .map(|a| self.score(wf, a, servers, grid, model))
            .collect()
    }

    /// The pool this backend effectively scores against, when it
    /// differs from the believed one — `None` (the default) means the
    /// believed laws are the scoring laws. Grid auto-sizing consults
    /// this so that a backend substituting *longer-tailed* measured
    /// laws (see [`EmpiricalBackend`]) gets an evaluation grid that
    /// covers those tails instead of silently truncating them.
    fn scoring_pool(&self, servers: &[Server]) -> Option<Vec<Server>> {
        let _ = servers;
        None
    }

    /// [`ScoreBackend::scoring_pool`] resolved against the believed
    /// pool: the substituted pool when the backend has one, the
    /// believed slice otherwise. This is the form grid-sizing call
    /// sites consume.
    fn resolve_scoring_pool<'s>(&self, servers: &'s [Server]) -> Cow<'s, [Server]> {
        match self.scoring_pool(servers) {
            Some(pool) => Cow::Owned(pool),
            None => Cow::Borrowed(servers),
        }
    }
}

/// The native analytic predictor: serial composition by PDF
/// convolution, parallel composition by CDF product, moments and
/// quantiles read off the grid — a thin [`ScoreBackend`] wrapper over
/// [`score_allocation_with`]. This is the default backend of every
/// [`Planner`](crate::plan::Planner) and the cross-check oracle for all
/// other backends.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AnalyticBackend;

impl ScoreBackend for AnalyticBackend {
    fn name(&self) -> &str {
        "analytic"
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        score_allocation_with(wf, alloc, servers, grid, model)
    }
}

/// Scores against *measured* service laws instead of the believed pool.
///
/// Each server with an attached sample set (raw observations or a
/// [`Empirical`] window) has its law re-fitted to the best Table-1
/// family ([`select_family`]) at construction; scoring substitutes the
/// fitted law for the believed one and runs the analytic engine.
/// Servers without samples keep their believed laws, so an empty
/// backend is bit-identical to [`AnalyticBackend`].
///
/// ```
/// use dcflow::prelude::*;
///
/// let wf = Workflow::tandem(2, 1.0);
/// let believed = Server::pool_exponential(&[3.0, 4.0]);
/// // server 0 actually serves at rate ~6: feed measurements in
/// let samples: Vec<f64> = (1..400).map(|i| (i as f64 / 400.0_f64).ln() / -6.0).collect();
/// let backend = EmpiricalBackend::new().with_samples(0, &samples);
/// let plan = Planner::new(&wf, &believed)
///     .backend(&backend)
///     .plan(&SdccPolicy)
///     .expect("feasible");
/// // measured server 0 is faster than believed => better mean than the
/// // purely-believed score
/// let believed_plan = Planner::new(&wf, &believed).plan(&SdccPolicy).unwrap();
/// assert!(plan.score.mean < believed_plan.score.mean);
/// ```
#[derive(Clone, Debug, Default)]
pub struct EmpiricalBackend {
    /// Fitted law per server id; `None` = keep the believed law.
    fitted: Vec<Option<ServiceDist>>,
}

impl EmpiricalBackend {
    /// Backend with no measurements (behaves like [`AnalyticBackend`]).
    pub fn new() -> EmpiricalBackend {
        EmpiricalBackend { fitted: Vec::new() }
    }

    /// Attach raw observed service times for `server_id` (fits the best
    /// Table-1 family immediately). Builder-style; panics on an empty
    /// sample slice.
    #[must_use]
    pub fn with_samples(mut self, server_id: usize, samples: &[f64]) -> EmpiricalBackend {
        assert!(!samples.is_empty(), "empirical backend needs samples");
        if self.fitted.len() <= server_id {
            self.fitted.resize(server_id + 1, None);
        }
        let (_, law, _) = select_family(samples);
        self.fitted[server_id] = Some(law);
        self
    }

    /// Attach an [`Empirical`] window (e.g. a monitor's sliding window)
    /// for `server_id`.
    #[must_use]
    pub fn with_empirical(self, server_id: usize, emp: &Empirical) -> EmpiricalBackend {
        self.with_samples(server_id, emp.sorted())
    }

    /// The fitted law for a server, if measurements were attached.
    pub fn law_for(&self, server_id: usize) -> Option<&ServiceDist> {
        self.fitted.get(server_id).and_then(|l| l.as_ref())
    }

    /// Number of servers with measured (fitted) laws.
    pub fn measured_servers(&self) -> usize {
        self.fitted.iter().filter(|l| l.is_some()).count()
    }

    /// The believed pool with measured laws substituted in.
    fn effective_pool(&self, servers: &[Server]) -> Vec<Server> {
        servers
            .iter()
            .map(|s| match self.law_for(s.id) {
                Some(law) => Server::new(s.id, law.clone()),
                None => s.clone(),
            })
            .collect()
    }
}

impl ScoreBackend for EmpiricalBackend {
    fn name(&self) -> &str {
        "empirical"
    }

    fn score(
        &self,
        wf: &Workflow,
        alloc: &Allocation,
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Score {
        match self.scoring_pool(servers) {
            None => score_allocation_with(wf, alloc, servers, grid, model),
            Some(pool) => score_allocation_with(wf, alloc, &pool, grid, model),
        }
    }

    /// One substituted pool per wave (not per candidate — the pool does
    /// not depend on the allocation).
    fn score_batch(
        &self,
        wf: &Workflow,
        allocs: &[Allocation],
        servers: &[Server],
        grid: &GridSpec,
        model: ResponseModel,
    ) -> Vec<Score> {
        let scoring = self.resolve_scoring_pool(servers);
        allocs
            .iter()
            .map(|a| score_allocation_with(wf, a, &scoring, grid, model))
            .collect()
    }

    fn scoring_pool(&self, servers: &[Server]) -> Option<Vec<Server>> {
        if self.fitted.iter().all(|l| l.is_none()) {
            return None;
        }
        Some(self.effective_pool(servers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{Planner, SdccPolicy};
    use crate::sched::allocate_with;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn fig6() -> (Workflow, Vec<Server>) {
        (
            Workflow::fig6(),
            Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]),
        )
    }

    #[test]
    fn analytic_backend_is_the_free_function_bit_for_bit() {
        // the satellite property: AnalyticBackend through Planner must be
        // bit-identical to a direct score_allocation_with call
        prop::run("AnalyticBackend == score_allocation_with", 25, |g| {
            let n = g.usize_in(2, 5);
            let wf = if g.bool(0.5) {
                Workflow::tandem(n, g.f64_in(0.3, 1.2))
            } else {
                Workflow::forkjoin(n, g.f64_in(0.3, 1.2))
            };
            let rates: Vec<f64> = (0..wf.slots()).map(|_| g.f64_in(3.0, 20.0)).collect();
            let servers = Server::pool_exponential(&rates);
            let Ok(alloc) = allocate_with(&wf, &servers, ResponseModel::Mm1) else {
                return; // infeasible draw
            };
            let grid = GridSpec::auto_response(&alloc, &servers, ResponseModel::Mm1);
            let direct = score_allocation_with(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);

            // via the trait object
            let backend: &dyn ScoreBackend = &AnalyticBackend;
            let via_trait = backend.score(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);
            assert_eq!(direct.mean, via_trait.mean);
            assert_eq!(direct.var, via_trait.var);
            assert_eq!(direct.p99, via_trait.p99);
            assert_eq!(direct.pdf, via_trait.pdf);

            // via the full Planner surface (injected backend + pinned grid)
            let via_planner = Planner::new(&wf, &servers)
                .backend(&AnalyticBackend)
                .grid(grid)
                .score(&alloc);
            assert_eq!(direct.mean, via_planner.mean);
            assert_eq!(direct.var, via_planner.var);
            assert_eq!(direct.p99, via_planner.p99);

            // and score_batch defaults to the same per-item scores
            let batch = backend.score_batch(
                &wf,
                std::slice::from_ref(&alloc),
                &servers,
                &grid,
                ResponseModel::Mm1,
            );
            assert_eq!(batch.len(), 1);
            assert_eq!(batch[0].mean, direct.mean);
        });
    }

    #[test]
    fn empty_empirical_backend_matches_analytic() {
        let (wf, servers) = fig6();
        let alloc = allocate_with(&wf, &servers, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto_response(&alloc, &servers, ResponseModel::Mm1);
        let a = AnalyticBackend.score(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);
        let e = EmpiricalBackend::new().score(&wf, &alloc, &servers, &grid, ResponseModel::Mm1);
        assert_eq!(a.mean, e.mean);
        assert_eq!(a.p99, e.p99);
    }

    #[test]
    fn empirical_backend_tracks_measured_laws() {
        // believed pool says all servers are Exp(2); measurements reveal
        // Exp(9..4). Scoring through the empirical backend must land close
        // to the truth-pool analytic score.
        let (wf, truth) = fig6();
        let believed = Server::pool_exponential(&[2.0; 6]);
        let mut rng = Rng::new(11);
        let mut backend = EmpiricalBackend::new();
        for (sid, s) in truth.iter().enumerate() {
            let samples: Vec<f64> = (0..4000).map(|_| s.dist.sample(&mut rng)).collect();
            backend = backend.with_samples(sid, &samples);
        }
        assert_eq!(backend.measured_servers(), 6);
        let alloc = allocate_with(&wf, &truth, ResponseModel::Mm1).unwrap();
        let grid = GridSpec::auto_response(&alloc, &truth, ResponseModel::Mm1);
        let want = AnalyticBackend.score(&wf, &alloc, &truth, &grid, ResponseModel::Mm1);
        let got = backend.score(&wf, &alloc, &believed, &grid, ResponseModel::Mm1);
        assert!(got.is_stable());
        assert!(
            (got.mean - want.mean).abs() < 0.10 * want.mean,
            "empirical {} vs truth {}",
            got.mean,
            want.mean
        );
    }

    #[test]
    fn auto_grid_covers_measured_tails() {
        // believed laws are short-tailed Exp(10); the measured law of
        // server 0 straggles with a ~25x longer tail. The planner's auto
        // grid must be sized against the scoring (measured) laws, so the
        // empirical score keeps its probability mass on the grid.
        let wf = Workflow::tandem(2, 1.0);
        let believed = Server::pool_exponential(&[10.0, 9.0]);
        let straggler = ServiceDist::straggler(10.0, 0.4, 0.08, 0.01);
        let mut rng = Rng::new(7);
        let samples: Vec<f64> = (0..6000).map(|_| straggler.sample(&mut rng)).collect();
        let backend = EmpiricalBackend::new().with_samples(0, &samples);
        assert!(backend.scoring_pool(&believed).is_some());
        let plan = Planner::new(&wf, &believed)
            .backend(&backend)
            .plan(&SdccPolicy)
            .expect("feasible");
        assert!(plan.score.is_stable());
        assert!(
            plan.score.mass > 0.95,
            "measured tail truncated: mass {}",
            plan.score.mass
        );
        // and the believed-law-only grid really would have truncated it
        let believed_grid = Planner::new(&wf, &believed)
            .plan(&SdccPolicy)
            .unwrap()
            .diagnostics
            .grid;
        assert!(
            plan.diagnostics.grid.t_max() > 2.0 * believed_grid.t_max(),
            "scoring-pool grid {:?} should be much wider than believed grid {:?}",
            plan.diagnostics.grid,
            believed_grid
        );
    }

    #[test]
    fn backend_names_are_stable() {
        assert_eq!(AnalyticBackend.name(), "analytic");
        assert_eq!(EmpiricalBackend::new().name(), "empirical");
    }
}
