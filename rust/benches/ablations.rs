//! ABL — ablations of the design choices DESIGN.md calls out:
//!
//!   A1  rate scheduling: equilibrium (Alg. 2) vs uniform split
//!   A2  allocation seed: Alg. 1/2 sort-matching vs random seeds
//!       (does the §3 balancing refinement rescue bad seeds?)
//!   A3  grid resolution G: score error + runtime vs G
//!   A4  monitor window: re-fit accuracy vs window length under drift
//!
//! Writes bench_out/ablations.csv.

use dcflow::prelude::*;
use dcflow::sched::{baseline_allocate_split, refine, schedule_rates};
use dcflow::util::bench::{bench, fmt_time, Csv};
use dcflow::util::rng::Rng;

fn main() {
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let planner = Planner::new(&wf, &servers)
        .model(model)
        .objective(Objective::Mean);
    let mut csv = Csv::new("ablations", "ablation,setting,mean,var,extra");

    // ---- A1: equilibrium vs uniform rate split --------------------------
    println!("== A1: rate scheduling (same placement, fig6) ==");
    let alloc = planner
        .plan(&ProposedPolicy::default())
        .unwrap()
        .allocation;
    let grid = GridSpec::auto_response(&alloc, &servers, model);
    // all exact scoring below goes through the builder surface on a
    // pinned grid (the analytic backend)
    let scored = planner.grid(grid);
    let eq = scored.score(&alloc);
    // same server placement, uniform splits
    let uni_alloc = baseline_allocate_split(&wf, &servers, model, SplitPolicy::Uniform)
        .map(|mut u| {
            u.slot_server = alloc.slot_server.clone();
            // recompute uniform rates for this placement: fig6 forks are
            // 2-wide, so uniform = half the DAP rate
            u.slot_rate = vec![4.0, 4.0, 4.0, 4.0, 1.0, 1.0];
            u
        })
        .unwrap();
    let uni = scored.score(&uni_alloc);
    println!("equilibrium: mean={:.4} var={:.4}", eq.mean, eq.var);
    println!("uniform    : mean={:.4} var={:.4}", uni.mean, uni.var);
    println!(
        "equilibrium improves mean by {:+.2}%",
        100.0 * (uni.mean - eq.mean) / uni.mean
    );
    assert!(eq.mean <= uni.mean + 1e-9, "equilibrium must not hurt");
    csv.row(&["A1".into(), "equilibrium".into(), format!("{:.6}", eq.mean), format!("{:.6}", eq.var), String::new()]);
    csv.row(&["A1".into(), "uniform".into(), format!("{:.6}", uni.mean), format!("{:.6}", uni.var), String::new()]);

    // ---- A2: seed quality vs refinement ----------------------------------
    println!("\n== A2: Alg.1/2 seed vs random seeds + refinement ==");
    let mut rng = Rng::new(42);
    let mut worst_refined: f64 = 0.0;
    let mut worst_raw: f64 = 0.0;
    for _ in 0..12 {
        let mut assign: Vec<usize> = (0..6).collect();
        rng.shuffle(&mut assign);
        let Ok(a) = schedule_rates(&wf, assign, &servers, model) else { continue };
        let raw = scored.score(&a);
        let (_, ref_s) = refine(&wf, a, &servers, &grid, model, Objective::Mean, 8).unwrap();
        worst_raw = worst_raw.max(raw.mean);
        worst_refined = worst_refined.max(ref_s.mean);
    }
    let seeded = planner.plan(&ProposedPolicy::default()).unwrap();
    println!("worst random raw     mean: {worst_raw:.4}");
    println!("worst random refined mean: {worst_refined:.4}");
    println!("Alg.1/2 + refine     mean: {:.4}", seeded.score.mean);
    assert!(
        worst_refined <= seeded.score.mean * 1.10,
        "refinement should rescue random seeds to within 10%"
    );
    csv.row(&["A2".into(), "random_raw_worst".into(), format!("{worst_raw:.6}"), String::new(), String::new()]);
    csv.row(&["A2".into(), "random_refined_worst".into(), format!("{worst_refined:.6}"), String::new(), String::new()]);
    csv.row(&["A2".into(), "alg12_refined".into(), format!("{:.6}", seeded.score.mean), String::new(), String::new()]);

    // ---- A3: grid resolution ---------------------------------------------
    println!("\n== A3: grid resolution (score error vs G, fig6) ==");
    let fine = GridSpec { dt: grid.dt * (grid.n as f64) / 8192.0, n: 8192 };
    let truth = planner.grid(fine).score(&alloc);
    println!("reference (G=8192): mean={:.6}", truth.mean);
    for g in [128usize, 256, 512, 1024, 2048] {
        let gs = GridSpec { dt: fine.dt * 8192.0 / g as f64, n: g };
        let gp = planner.grid(gs);
        let t = bench(1, 5, || gp.score(&alloc));
        let s = gp.score(&alloc);
        let err = 100.0 * (s.mean - truth.mean).abs() / truth.mean;
        println!(
            "G={g:>5}: mean={:.6} err={err:.3}% time={}",
            s.mean,
            fmt_time(t.mean_s)
        );
        csv.row(&["A3".into(), format!("G={g}"), format!("{:.6}", s.mean), format!("{err:.4}"), format!("{:.3}", t.ns() / 1e3)]);
    }

    // ---- A4: monitor window under drift ------------------------------------
    println!("\n== A4: monitor window vs re-fit accuracy under drift ==");
    let old = ServiceDist::exponential(9.0);
    let new = ServiceDist::exponential(3.0);
    for window in [256usize, 1024, 4096] {
        let mut mon = ServerMonitor::new(window);
        let mut r = Rng::new(7);
        for _ in 0..6000 {
            mon.observe(old.sample(&mut r));
        }
        for _ in 0..1500 {
            mon.observe(new.sample(&mut r));
        }
        let fitted = fit_delayed_exponential(&mon.window_samples());
        let err = 100.0 * (fitted.mean() - new.mean()).abs() / new.mean();
        println!(
            "window={window:>5}: fitted mean={:.4} (true {:.4}) err={err:.1}%",
            fitted.mean(),
            new.mean()
        );
        csv.row(&["A4".into(), format!("window={window}"), format!("{:.6}", fitted.mean()), format!("{err:.3}"), String::new()]);
    }
    println!("\n(small windows adapt faster but fit noisier laws — the re-opt cadence trade-off)");
    csv.flush();
    println!("ABL OK");
}
