//! PERF — wave-batched cross-job swap refinement: the multi-job
//! engine's serial reference pass vs the wave and incremental engines,
//! across shard counts {1, 2, 8}. The cross-job swap phase scores every
//! (job-pair × server-pair) exchange per round; the wave engine turns
//! that into wide `score_batch` calls a `ShardedBackend` fans across
//! worker threads — the last hot loop PR 3's sharding could not reach.
//! The incremental engine additionally carries a cross-round memo
//! (`sched::memo`) so rounds after the first only re-score pair-waves
//! touching a mutated plan; its rows include the memo counters.
//!
//! Documented in docs/BENCHMARKS.md. Writes bench_out/multijob_swap.csv;
//! the reproducible JSON twin is `examples/multijob_bench.rs`
//! (BENCH_multijob.json).

use dcflow::prelude::*;
use dcflow::util::bench::{bench, fmt_time, Csv};

fn main() {
    println!("== PERF: multi-job cross-job swap — serial loop vs wave engine ==");
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("available parallelism: {cpus}");
    let mut csv = Csv::new("multijob_swap", "metric,value,unit");
    csv.row(&["cpus".into(), format!("{cpus}"), "threads".into()]);

    // four concurrent jobs over a 14-server heterogeneous pool
    // (6 + 3 + 2 + 2 = 13 slots, one spare)
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let j4 = Workflow::tandem(2, 3.0);
    let jobs = [&j1, &j2, &j3, &j4];
    let servers = Server::pool_exponential(&[
        18.0, 16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.5, 4.0,
    ]);
    println!("jobs: {}, servers: {}", jobs.len(), servers.len());

    // serial reference pass (per-candidate ScoreBackend::score calls)
    let serial_planner = Planner::new(&j1, &servers)
        .objective(Objective::Mean)
        .swap_engine(SwapEngine::Serial);
    let reference = serial_planner.plan_jobs(&jobs).expect("feasible");
    let t_serial = bench(1, 3, || serial_planner.plan_jobs(&jobs).unwrap());
    println!(
        "serial swap loop          : {} (cluster objective {:.4})",
        fmt_time(t_serial.mean_s),
        cluster_objective(&reference, &jobs, Objective::Mean)
    );
    csv.row(&[
        "serial_plan_jobs_s".into(),
        format!("{:.6}", t_serial.mean_s),
        "s".into(),
    ]);

    // wave engine × shard counts; every configuration must reproduce
    // the reference plans bit for bit before its timing counts
    let mut best_speedup = 0.0f64;
    for shards in [1usize, 2, 8] {
        let backend = ShardedBackend::new(&AnalyticBackend, shards);
        let planner = Planner::new(&j1, &servers)
            .objective(Objective::Mean)
            .backend(&backend);
        let got = planner.plan_jobs(&jobs).expect("feasible");
        assert_eq!(got.len(), reference.len());
        for (g, r) in got.iter().zip(reference.iter()) {
            assert_eq!(g.alloc, r.alloc, "wave x{shards} diverged from serial");
            assert_eq!(g.score.mean, r.score.mean);
            assert_eq!(g.score.p99, r.score.p99);
            assert_eq!(g.grid, r.grid);
        }
        let t = bench(1, 3, || planner.plan_jobs(&jobs).unwrap());
        let speedup = t_serial.mean_s / t.mean_s;
        best_speedup = best_speedup.max(speedup);
        println!(
            "wave engine, {shards} shard(s)   : {} (speedup {speedup:.2}x)",
            fmt_time(t.mean_s)
        );
        csv.row(&[
            format!("wave_x{shards}_plan_jobs_s"),
            format!("{:.6}", t.mean_s),
            "s".into(),
        ]);
        csv.row(&[
            format!("wave_x{shards}_speedup"),
            format!("{speedup:.3}"),
            "x".into(),
        ]);
    }
    // incremental engine × shard counts: same bit-identity gate before
    // any timing, plus the memo counters from a single reported run
    // (the engine is deterministic, so one report speaks for all)
    let mut memo_logged = false;
    for shards in [1usize, 2, 8] {
        let backend = ShardedBackend::new(&AnalyticBackend, shards);
        let planner = Planner::new(&j1, &servers)
            .objective(Objective::Mean)
            .backend(&backend)
            .swap_engine(SwapEngine::Incremental);
        let (got, stats) = planner.plan_jobs_report(&jobs).expect("feasible");
        assert_eq!(got.len(), reference.len());
        for (g, r) in got.iter().zip(reference.iter()) {
            assert_eq!(g.alloc, r.alloc, "incremental x{shards} diverged from serial");
            assert_eq!(g.score.mean, r.score.mean);
            assert_eq!(g.score.p99, r.score.p99);
            assert_eq!(g.grid, r.grid);
        }
        if !memo_logged {
            memo_logged = true;
            println!(
                "memo (any shard count)    : {} hits / {} misses / {} invalidated (hit rate {:.3})",
                stats.memo_hits,
                stats.memo_misses,
                stats.memo_invalidated,
                stats.hit_rate()
            );
            csv.row(&[
                "incremental_memo_hit_rate".into(),
                format!("{:.4}", stats.hit_rate()),
                "ratio".into(),
            ]);
            csv.row(&[
                "incremental_memo_hits".into(),
                format!("{}", stats.memo_hits),
                "sides".into(),
            ]);
            csv.row(&[
                "incremental_memo_misses".into(),
                format!("{}", stats.memo_misses),
                "sides".into(),
            ]);
            csv.row(&[
                "incremental_memo_invalidated".into(),
                format!("{}", stats.memo_invalidated),
                "sides".into(),
            ]);
        }
        let t = bench(1, 3, || planner.plan_jobs(&jobs).unwrap());
        let speedup = t_serial.mean_s / t.mean_s;
        best_speedup = best_speedup.max(speedup);
        println!(
            "incremental, {shards} shard(s)   : {} (speedup {speedup:.2}x)",
            fmt_time(t.mean_s)
        );
        csv.row(&[
            format!("incremental_x{shards}_plan_jobs_s"),
            format!("{:.6}", t.mean_s),
            "s".into(),
        ]);
        csv.row(&[
            format!("incremental_x{shards}_speedup"),
            format!("{speedup:.3}"),
            "x".into(),
        ]);
    }
    csv.flush();

    if cpus > 1 && best_speedup <= 1.0 {
        println!("WARNING: no wave speedup on a {cpus}-way machine");
    }
    println!("PERF OK (best speedup {best_speedup:.2}x, plans bit-identical)");
}
