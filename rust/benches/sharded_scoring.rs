//! PERF — sharded wave scoring: `ShardedBackend` vs the serial inner
//! backend on wide candidate waves over a many-server pool, plus the
//! end-to-end multi-job planner. Both dispatch modes are measured —
//! the persistent pooled fabric (default) against the spawn-per-wave
//! scoped pool — so the fixed cost the fabric removes is visible as a
//! pooled-vs-scoped delta at every shard count. The paper's
//! response-time tails grow with the number of series/parallel servers,
//! so realistic plans need wide searches exactly where single-threaded
//! `score_batch` bottlenecks.
//!
//! Reported in EXPERIMENTS.md §Perf. Writes bench_out/sharded_scoring.csv.

use dcflow::prelude::*;
use dcflow::sched::schedule_rates;
use dcflow::util::bench::{bench, fmt_time, Csv};
use dcflow::util::rng::Rng;

/// Random injective assignments of the workflow's slots onto a larger
/// pool, rate-scheduled into candidate allocations.
fn candidate_wave(
    wf: &Workflow,
    servers: &[Server],
    n: usize,
    seed: u64,
) -> Vec<Allocation> {
    let mut rng = Rng::new(seed);
    let mut wave = Vec::with_capacity(n);
    let mut ids: Vec<usize> = (0..servers.len()).collect();
    while wave.len() < n {
        rng.shuffle(&mut ids);
        let assign: Vec<usize> = ids[..wf.slots()].to_vec();
        if let Ok(a) = schedule_rates(wf, assign, servers, ResponseModel::Mm1) {
            wave.push(a);
        }
    }
    wave
}

fn main() {
    println!("== PERF: sharded vs serial wave scoring ==");
    let cpus = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("available parallelism: {cpus}");
    let mut csv = Csv::new("sharded_scoring", "metric,value,unit");
    csv.row(&["cpus".into(), format!("{cpus}"), "threads".into()]);

    // --- wave scoring on a 12-server pool -------------------------------
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[
        16.0, 14.0, 12.0, 11.0, 10.0, 9.0, 8.0, 7.5, 7.0, 6.0, 5.0, 4.0,
    ]);
    let wave = candidate_wave(&wf, &servers, 256, 7);
    let grid = GridSpec::auto_response(&wave[0], &servers, ResponseModel::Mm1);
    println!("wave: {} candidates, {} servers, {}-point grid", wave.len(), servers.len(), grid.n);

    let serial = AnalyticBackend;
    let t_serial = bench(1, 5, || {
        serial.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1)
    });
    println!(
        "serial score_batch (256)  : {} ({:.0} candidates/s)",
        fmt_time(t_serial.mean_s),
        wave.len() as f64 / t_serial.mean_s
    );
    csv.row(&[
        "serial_wave_s".into(),
        format!("{:.6}", t_serial.mean_s),
        "s".into(),
    ]);

    // correctness smoke: both dispatch modes must equal serial bit for
    // bit — identity is asserted before either mode is allowed to time
    let reference = serial.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
    let mut best_speedup = 0.0f64;
    for (mode, dispatch) in [
        ("pooled", Dispatch::Pooled),
        ("scoped", Dispatch::SpawnPerWave),
    ] {
        for shards in [2usize, 4, cpus.max(2)] {
            let backend = ShardedBackend::new(&serial, shards).dispatch(dispatch);
            let got = backend.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1);
            assert_eq!(got.len(), reference.len());
            for (g, r) in got.iter().zip(reference.iter()) {
                assert_eq!(g.mean, r.mean, "{mode} wave diverged from serial");
                assert_eq!(g.p99, r.p99);
            }
            let t = bench(1, 5, || {
                backend.score_batch(&wf, &wave, &servers, &grid, ResponseModel::Mm1)
            });
            let speedup = t_serial.mean_s / t.mean_s;
            best_speedup = best_speedup.max(speedup);
            println!(
                "{mode} x{shards:<2} (256)         : {} (speedup {speedup:.2}x)",
                fmt_time(t.mean_s)
            );
            csv.row(&[
                format!("{mode}_x{shards}_wave_s"),
                format!("{:.6}", t.mean_s),
                "s".into(),
            ]);
            csv.row(&[
                format!("{mode}_x{shards}_speedup"),
                format!("{speedup:.3}"),
                "x".into(),
            ]);
            if let Some(fs) = backend.fabric_stats() {
                csv.row(&[
                    format!("{mode}_x{shards}_scratch_allocs"),
                    format!("{}", fs.scratch_allocs),
                    "buffers".into(),
                ]);
            }
        }
    }

    // --- end-to-end multi-job planning ----------------------------------
    let j1 = Workflow::fig6();
    let j2 = Workflow::tandem(3, 1.0);
    let j3 = Workflow::forkjoin(2, 2.0);
    let jobs = [&j1, &j2, &j3];
    let planner = Planner::new(&j1, &servers).objective(Objective::Mean);
    let t_jobs_serial = bench(1, 3, || planner.plan_jobs(&jobs).unwrap());
    let sharded = ShardedBackend::per_cpu(&AnalyticBackend);
    let sharded_planner = Planner::new(&j1, &servers)
        .objective(Objective::Mean)
        .backend(&sharded);
    let t_jobs_sharded = bench(1, 3, || sharded_planner.plan_jobs(&jobs).unwrap());
    println!(
        "plan_jobs serial (3 jobs) : {}\nplan_jobs sharded x{}     : {} (speedup {:.2}x)",
        fmt_time(t_jobs_serial.mean_s),
        sharded.shards(),
        fmt_time(t_jobs_sharded.mean_s),
        t_jobs_serial.mean_s / t_jobs_sharded.mean_s
    );
    csv.row(&[
        "plan_jobs_serial_s".into(),
        format!("{:.6}", t_jobs_serial.mean_s),
        "s".into(),
    ]);
    csv.row(&[
        "plan_jobs_sharded_s".into(),
        format!("{:.6}", t_jobs_sharded.mean_s),
        "s".into(),
    ]);
    csv.flush();

    if cpus > 1 && best_speedup <= 1.0 {
        println!("WARNING: no sharded speedup on a {cpus}-way machine");
    }
    println!("PERF OK (best wave speedup {best_speedup:.2}x)");
}
