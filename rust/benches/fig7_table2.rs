//! FIG7 + TAB2 — the paper's evaluation: response-time distribution of
//! baseline vs ours vs optimal on the Fig. 6 workflow (Fig. 7a/7b), and
//! the three-scenario mean/variance table (Table 2), all driven through
//! `Planner::compare` (one common grid per scenario).
//!
//! Paper parameters: λ_DAP = 8/4/2, six servers with service rates
//! 9,8,7,6,5,4. Scenario laws (Table 2 leaves their parameters open; we
//! fix them and record the choice in EXPERIMENTS.md):
//!   S1  delayed exponential  (delay = 20% of each server's mean)
//!   S2  delayed pareto       (matched means, heavy tails)
//!   S3  mixed DE/DP + one straggler mode
//! Every scheme is scored analytically AND validated by DES on the same
//! allocation. Writes bench_out/fig7_curves.csv and bench_out/table2.csv.

use dcflow::compose::moments::cdf_from_pdf;
use dcflow::prelude::*;
use dcflow::util::bench::Csv;

/// Delayed exponential with total mean 1/mu, delay = frac of the mean.
fn de(mu: f64, frac: f64) -> ServiceDist {
    let mean = 1.0 / mu;
    let delay = frac * mean;
    ServiceDist::delayed_exponential(1.0 / (mean - delay), delay)
}

/// Delayed pareto with mean matched to 1/mu (numerically tuned lam).
fn dp(mu: f64) -> ServiceDist {
    let target = 1.0 / mu;
    // pareto tail with finite variance needs lam > 2; search lam so the
    // (cached) mean hits the target
    let (mut lo, mut hi) = (2.2, 400.0);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if ServiceDist::delayed_pareto(mid, 0.1 * target).mean() > target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    ServiceDist::delayed_pareto(0.5 * (lo + hi), 0.1 * target)
}

fn scenario(id: usize) -> (String, Vec<Server>, ResponseModel) {
    let mus = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0];
    match id {
        1 => (
            "S1 delayed-exponential".into(),
            mus.iter()
                .enumerate()
                .map(|(i, &mu)| Server::new(i, de(mu, 0.2)))
                .collect(),
            ResponseModel::Mg1,
        ),
        2 => (
            "S2 delayed-pareto".into(),
            mus.iter()
                .enumerate()
                .map(|(i, &mu)| Server::new(i, dp(mu)))
                .collect(),
            ResponseModel::Mg1,
        ),
        _ => (
            "S3 mixed + straggler".into(),
            vec![
                Server::new(0, de(9.0, 0.2)),
                Server::new(1, dp(8.0)),
                Server::new(2, de(7.0, 0.3)),
                Server::new(3, dp(6.0)),
                Server::new(
                    4,
                    ServiceDist::multimodal(vec![
                        (0.92, Mode::continuous(6.5, 0.02, TailKind::Exponential)),
                        (0.08, Mode::continuous(1.0, 0.25, TailKind::Exponential)),
                    ]),
                ),
                Server::new(5, de(4.0, 0.2)),
            ],
            ResponseModel::Mg1,
        ),
    }
}

struct Row {
    scheme: String,
    analytic: Score,
    sim_mean: f64,
    sim_var: f64,
}

fn eval(wf: &Workflow, servers: &[Server], plan: &Plan) -> Row {
    let sim = simulate(
        wf,
        &plan.allocation,
        servers,
        &SimConfig {
            n_tasks: 150_000,
            warmup: 10_000,
            seed: 0xF167,
            queueing: true,
        },
    );
    Row {
        scheme: plan.policy_name.clone(),
        analytic: plan.score.clone(),
        sim_mean: sim.mean,
        sim_var: sim.var,
    }
}

/// Fig. 6 with all DAP rates scaled by k (the paper does not pin the
/// utilization its Table-2 scenarios ran at; we report k = 1.0 — the
/// literal reading — and k = 1.4, where the baseline's homogeneity
/// assumption starts to really hurt; see EXPERIMENTS.md).
fn fig6_scaled(k: f64) -> Workflow {
    let root = Dcc::serial_with_rates(
        vec![
            Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::serial(vec![Dcc::queue(), Dcc::queue()]),
            Dcc::parallel(vec![Dcc::queue(), Dcc::queue()]),
        ],
        vec![Some(8.0 * k), Some(4.0 * k), Some(2.0 * k)],
    );
    Workflow::new(root, 8.0 * k).expect("valid")
}

/// ours / optimal / baseline on one common grid via the planner.
fn bakeoff(wf: &Workflow, servers: &[Server], model: ResponseModel) -> Vec<Plan> {
    Planner::new(wf, servers)
        .model(model)
        .objective(Objective::Mean)
        .compare(&[&ProposedPolicy::default(), &OptimalPolicy, &BaselinePolicy::default()])
        .into_iter()
        .collect::<Result<_, _>>()
        .expect("fig6 scenarios are feasible")
}

fn main() {
    let mut table = Csv::new(
        "table2",
        "scenario,load,scheme,mean,var,p99,sim_mean,sim_var,mean_improve_pct,var_improve_pct",
    );

    for (sid, load) in [(1, 1.0), (2, 1.0), (3, 1.0), (1, 1.4), (2, 1.4), (3, 1.4)] {
        let wf = fig6_scaled(load);
        let (name, servers, model) = scenario(sid);
        println!("\n== TAB2 {name} @ load x{load} ==");
        let plans = bakeoff(&wf, &servers, model);
        let rows: Vec<Row> = plans.iter().map(|p| eval(&wf, &servers, p)).collect();

        println!(
            "{:<10} {:>9} {:>9} {:>9} {:>10} {:>10}",
            "scheme", "mean", "var", "p99", "sim_mean", "sim_var"
        );
        let base = &rows[2];
        for r in &rows {
            println!(
                "{:<10} {:>9.4} {:>9.4} {:>9.4} {:>10.4} {:>10.4}",
                r.scheme, r.analytic.mean, r.analytic.var, r.analytic.p99, r.sim_mean, r.sim_var
            );
            let mi = 100.0 * (base.analytic.mean - r.analytic.mean) / base.analytic.mean;
            let vi = 100.0 * (base.analytic.var - r.analytic.var) / base.analytic.var;
            table.row(&[
                name.clone(),
                format!("{load}"),
                r.scheme.clone(),
                format!("{:.6}", r.analytic.mean),
                format!("{:.6}", r.analytic.var),
                format!("{:.6}", r.analytic.p99),
                format!("{:.6}", r.sim_mean),
                format!("{:.6}", r.sim_var),
                format!("{mi:.2}"),
                format!("{vi:.2}"),
            ]);
        }
        let ours = &rows[0];
        let opt = &rows[1];
        println!(
            "improvement over baseline: mean {:+.1}%  var {:+.1}%  (optimal: {:+.1}% / {:+.1}%)",
            100.0 * (base.analytic.mean - ours.analytic.mean) / base.analytic.mean,
            100.0 * (base.analytic.var - ours.analytic.var) / base.analytic.var,
            100.0 * (base.analytic.mean - opt.analytic.mean) / base.analytic.mean,
            100.0 * (base.analytic.var - opt.analytic.var) / base.analytic.var,
        );
        // paper's ordering: optimal <= ours <= baseline (mean)
        assert!(opt.analytic.mean <= ours.analytic.mean + 1e-6);
        assert!(ours.analytic.mean <= base.analytic.mean + 1e-6);
    }
    table.flush();

    // ---- FIG7: response-time distribution curves (scenario 1) ----------
    println!("\n== FIG7 curves (scenario S1) ==");
    let wf = Workflow::fig6();
    let (_, servers, model) = scenario(1);
    let plans = bakeoff(&wf, &servers, model);
    let grid = plans[0].diagnostics.grid;
    let (ours, opt, base) = (&plans[0].score, &plans[1].score, &plans[2].score);
    let (oc, pc, bc) = (
        cdf_from_pdf(&ours.pdf, grid.dt),
        cdf_from_pdf(&opt.pdf, grid.dt),
        cdf_from_pdf(&base.pdf, grid.dt),
    );
    let mut curves = Csv::new(
        "fig7_curves",
        "t,ours_pdf,optimal_pdf,baseline_pdf,ours_cdf,optimal_cdf,baseline_cdf",
    );
    for k in (0..grid.n).step_by(4) {
        curves.rowf(&[
            k as f64 * grid.dt,
            ours.pdf[k],
            opt.pdf[k],
            base.pdf[k],
            oc[k],
            pc[k],
            bc[k],
        ]);
    }
    curves.flush();
    println!("FIG7/TAB2 OK");
}
