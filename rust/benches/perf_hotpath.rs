//! PERF — hot-path throughput: candidate-allocation scoring (the
//! optimizer's inner loop) across backends, plus the convolution
//! microbenchmarks that correspond to the L1 kernel.
//!
//! Reported in EXPERIMENTS.md §Perf. Writes bench_out/perf_hotpath.csv.

use dcflow::compose::conv::{conv_direct, conv_fft};
use dcflow::compose::grid::GridSpec;
use dcflow::dist::ServiceDist;
use dcflow::flow::Workflow;
use dcflow::plan::Planner;
use dcflow::runtime::scorer::BatchScorer;
use dcflow::runtime::ScorerEngine;
use dcflow::sched::server::Server;
use dcflow::sched::{schedule_rates, Allocation, ResponseModel};
use dcflow::util::bench::{bench, fmt_time, Csv};
use dcflow::util::rng::Rng;

fn permutation_wave(n: usize, seed: u64) -> Vec<Vec<usize>> {
    // n random permutations of 0..6
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut p: Vec<usize> = (0..6).collect();
            rng.shuffle(&mut p);
            p
        })
        .collect()
}

fn main() {
    println!("== PERF: allocation-scoring hot path ==");
    let wf = Workflow::fig6();
    let servers = Server::pool_exponential(&[9.0, 8.0, 7.0, 6.0, 5.0, 4.0]);
    let model = ResponseModel::Mm1;
    let mut csv = Csv::new("perf_hotpath", "metric,value,unit");

    // prepare a wave of rate-scheduled candidate allocations
    let waves: Vec<Allocation> = permutation_wave(64, 1)
        .into_iter()
        .filter_map(|assign| schedule_rates(&wf, assign, &servers, model).ok())
        .collect();
    println!("candidates in wave: {}", waves.len());
    let grid = GridSpec::auto_response(&waves[0], &servers, model);
    let scorer_planner = Planner::new(&wf, &servers).model(model).grid(grid);

    // --- native single scoring (builder surface, analytic backend) ------
    let t_native_one = bench(3, 20, || scorer_planner.score(&waves[0]));
    println!(
        "native single score       : {} ({:.0}/s)",
        fmt_time(t_native_one.mean_s),
        t_native_one.per_sec()
    );
    csv.row(&[
        "native_single_score_us".into(),
        format!("{:.3}", t_native_one.ns() / 1e3),
        "us".into(),
    ]);

    // --- native batch ----------------------------------------------------
    let mut native = BatchScorer::native();
    let t_native = bench(2, 10, || {
        native.score_batch(&wf, &waves, &servers, &grid, model)
    });
    let per_cand_native = t_native.mean_s / waves.len() as f64;
    println!(
        "native batch (64)         : {} ({:.0} candidates/s)",
        fmt_time(t_native.mean_s),
        1.0 / per_cand_native
    );
    csv.row(&[
        "native_batch_cand_per_s".into(),
        format!("{:.1}", 1.0 / per_cand_native),
        "cand/s".into(),
    ]);

    // --- XLA batch (AOT artifacts, A/B: fast FFT vs pallas-interpret) ----
    // Measured baseline on this box (pallas-interpret artifact, §Perf
    // "before"): 144.8 s / 64-candidate batch — the interpret-mode pallas
    // grid lowers to an XLA while-loop of dynamic slices on CPU. The
    // `score_fig6_fast` artifact replaces the convolution with rfft/irfft
    // ("after"). Set DCFLOW_PERF_PALLAS=1 to re-measure the slow one.
    let fast = dcflow::runtime::executable::ArtifactRegistry::open_default()
        .ok()
        .and_then(|reg| {
            let name = reg
                .names()
                .iter()
                .find(|n| n.starts_with("score_fig6_fast"))?
                .to_string();
            BatchScorer::xla_with(reg, &name).ok()
        });
    if let Some(mut xla) = fast {
        assert_eq!(xla.backend(), ScorerEngine::Xla);
        let xgrid = GridSpec { dt: grid.dt, n: xla.grid_n };
        let t_compile = bench(0, 1, || {
            xla.score_batch(&wf, &waves, &servers, &xgrid, model)
        });
        println!("xla(fast) compile+first   : {}", fmt_time(t_compile.mean_s));
        let t_xla = bench(1, 5, || {
            xla.score_batch(&wf, &waves, &servers, &xgrid, model)
        });
        let per_cand = t_xla.mean_s / waves.len() as f64;
        println!(
            "xla(fast) batch (64)      : {} ({:.0} candidates/s)",
            fmt_time(t_xla.mean_s),
            1.0 / per_cand
        );
        csv.row(&[
            "xla_fast_batch_cand_per_s".into(),
            format!("{:.1}", 1.0 / per_cand),
            "cand/s".into(),
        ]);
        println!(
            "xla(fast) vs native per-candidate speedup: {:.2}x",
            per_cand_native / per_cand
        );
        csv.row(&[
            "xla_fast_speedup_vs_native".into(),
            format!("{:.3}", per_cand_native / per_cand),
            "x".into(),
        ]);
        // NOTE: score_batch auto-prefers the fully-fused parametric
        // (mmde) artifact when response laws allow — which they do for
        // M/M/1 exponential pools — so the numbers above already measure
        // the parametric path when artifacts are current. To compare the
        // grid-marshalling path, xla_with pins score_fig6_fast_* without
        // the mmde preference only when the mmde artifact is missing.
    } else {
        println!("xla/pjrt batch            : skipped (run `make artifacts`)");
    }
    if std::env::var("DCFLOW_PERF_PALLAS").is_ok() {
        if let Ok(reg) = dcflow::runtime::executable::ArtifactRegistry::open_default() {
            let name = reg
                .names()
                .iter()
                .find(|n| n.starts_with("score_fig6_b"))
                .map(|s| s.to_string());
            if let Some(name) = name {
                let mut slow = BatchScorer::xla_with(reg, &name).unwrap();
                let xgrid = GridSpec { dt: grid.dt, n: slow.grid_n };
                let t = bench(0, 1, || {
                    slow.score_batch(&wf, &waves, &servers, &xgrid, model)
                });
                println!("xla(pallas-interpret)     : {} (before-optimization baseline)", fmt_time(t.mean_s));
                csv.row(&[
                    "xla_pallas_batch_s".into(),
                    format!("{:.3}", t.mean_s),
                    "s".into(),
                ]);
            }
        }
    }

    // --- convolution micro (the L1 kernel's native twin) ------------------
    println!("\n== PERF: convolution backends (G-point grids) ==");
    for g in [512usize, 1024, 2048, 4096] {
        let dt = 20.0 / g as f64;
        let a = ServiceDist::exponential(2.0).pdf_grid(dt, g);
        let b = ServiceDist::exponential(5.0).pdf_grid(dt, g);
        let td = bench(2, 8, || conv_direct(&a, &b, dt));
        let tf = bench(2, 20, || conv_fft(&a, &b, dt));
        println!(
            "G={g:>5}: direct {} | fft {} | speedup {:.1}x",
            fmt_time(td.mean_s),
            fmt_time(tf.mean_s),
            td.mean_s / tf.mean_s
        );
        csv.row(&[
            format!("conv_fft_g{g}_us"),
            format!("{:.3}", tf.ns() / 1e3),
            "us".into(),
        ]);
    }

    // --- end-to-end optimizer sweep (planner surface) ---------------------
    use dcflow::plan::{OptimalPolicy, ProposedPolicy};
    use dcflow::sched::Objective;
    let planner = Planner::new(&wf, &servers)
        .model(model)
        .objective(Objective::Mean);
    let t_prop = bench(1, 5, || planner.plan(&ProposedPolicy::default()).unwrap());
    let t_opt = bench(1, 3, || {
        planner.grid(grid).plan(&OptimalPolicy).unwrap()
    });
    println!(
        "\nplan(proposed) (fig6)     : {}\nplan(optimal)  (720)      : {}",
        fmt_time(t_prop.mean_s),
        fmt_time(t_opt.mean_s)
    );
    csv.row(&[
        "plan_proposed_ms".into(),
        format!("{:.3}", t_prop.ns() / 1e6),
        "ms".into(),
    ]);
    csv.row(&[
        "plan_optimal_ms".into(),
        format!("{:.3}", t_opt.ns() / 1e6),
        "ms".into(),
    ]);
    csv.flush();
    println!("PERF OK");
}
