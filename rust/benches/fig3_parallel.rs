//! FIG3 — paper Fig. 3a/3b: CDF and PDF of the completion time of 10–50
//! *parallel* exponential servers (fork–join).
//!
//! Three-way agreement (closed form: max-CDF product + harmonic-number
//! moments; analytic grid engine; DES), plus the paper's comparative
//! claim: the parallel tail grows much slower than the serial tail.
//! Writes bench_out/fig3_{cdf,pdf,moments}.csv.

use dcflow::compose::analytic::{max_exp_cdf, max_iid_exp_mean, max_iid_exp_var};
use dcflow::compose::maxcomp::parallel_compose;
use dcflow::compose::moments::moments;
use dcflow::dist::ServiceDist;
use dcflow::sim::network::{simulate_parallel_iid, SimConfig};
use dcflow::util::bench::{bench, fmt_time, Csv};

fn main() {
    println!("== FIG3: parallel (fork-join) tail growth (10..50 x Exp(1)) ==");
    let ns = [10usize, 20, 30, 40, 50];
    let (g, dt) = (4096usize, 12.0 / 4096.0);
    let d = ServiceDist::exponential(1.0);

    let mut cdf_csv = Csv::new("fig3_cdf", "t,n10,n20,n30,n40,n50");
    let mut pdf_csv = Csv::new("fig3_pdf", "t,n10,n20,n30,n40,n50");
    let mut mom_csv = Csv::new(
        "fig3_moments",
        "n,mean_analytic,var_analytic,mean_grid,var_grid,mean_sim,var_sim",
    );

    let base_cdf = d.cdf_grid(dt, g);
    let cfg = SimConfig {
        n_tasks: 100_000,
        warmup: 0,
        seed: 20260711,
        queueing: false,
    };

    println!(
        "{:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "n", "mean(anal)", "var(anal)", "mean(grid)", "var(grid)", "mean(sim)", "var(sim)"
    );
    let mut curves = Vec::new();
    for &n in &ns {
        let cdfs: Vec<Vec<f64>> = (0..n).map(|_| base_cdf.clone()).collect();
        let (cdf, pdf) = parallel_compose(&cdfs, dt);
        let (gm, gv) = moments(&pdf, dt);
        let am = max_iid_exp_mean(n as u32, 1.0);
        let av = max_iid_exp_var(n as u32, 1.0);
        let sim = simulate_parallel_iid(1.0, n, &cfg);
        println!(
            "{n:>4} {am:>12.3} {av:>12.3} {gm:>12.3} {gv:>12.3} {:>12.3} {:>12.3}",
            sim.mean, sim.var
        );
        mom_csv.rowf(&[n as f64, am, av, gm, gv, sim.mean, sim.var]);
        assert!((gm - am).abs() < 0.03 * am, "grid mean {gm} vs {am}");
        assert!((sim.mean - am).abs() < 0.03 * am, "sim mean {} vs {am}", sim.mean);
        // spot-check against Eq. 4 generalized
        for k in (16..g).step_by(409) {
            let t = k as f64 * dt;
            let want = max_exp_cdf(t, &vec![1.0; n]);
            assert!((cdf[k] - want).abs() < 1e-9, "n={n} t={t}");
        }
        curves.push((cdf, pdf));
    }

    for k in (0..g).step_by(8) {
        let t = k as f64 * dt;
        let mut c_row = vec![t];
        let mut p_row = vec![t];
        for (cdf, pdf) in &curves {
            c_row.push(cdf[k]);
            p_row.push(pdf[k]);
        }
        cdf_csv.rowf(&c_row);
        pdf_csv.rowf(&p_row);
    }
    cdf_csv.flush();
    pdf_csv.flush();
    mom_csv.flush();

    // the paper's comparison: serial mean grows ~5x from n=10 to 50,
    // parallel only ~H50/H10 ~ 1.54x
    let m10 = max_iid_exp_mean(10, 1.0);
    let m50 = max_iid_exp_mean(50, 1.0);
    println!(
        "\nparallel growth 10->50: {:.2}x (serial: 5.00x) — parallel effect is weaker, as the paper notes",
        m50 / m10
    );
    assert!(m50 / m10 < 1.7);

    let cdfs: Vec<Vec<f64>> = (0..50).map(|_| base_cdf.clone()).collect();
    let t = bench(2, 10, || parallel_compose(&cdfs, dt));
    println!(
        "perf: 50-branch parallel compose on {g}-point grid: {} / iter",
        fmt_time(t.mean_s)
    );
    println!("FIG3 OK");
}
