//! FIG2 — paper Fig. 2a/2b: CDF and PDF of the end-to-end service time
//! of 10–50 *serial* exponential servers.
//!
//! Regenerates the curves three ways and checks they agree:
//!   1. closed form (Erlang),
//!   2. the analytic engine (grid convolution — the L1/L2 math),
//!   3. DES (Monte-Carlo).
//! Writes bench_out/fig2_{cdf,pdf,moments}.csv and prints the mean/var
//! growth table (the paper's "tail grows with serial scale" claim).

use dcflow::compose::analytic::{erlang_cdf, erlang_pdf};
use dcflow::compose::conv::serial_compose;
use dcflow::compose::moments::{cdf_from_pdf, moments};
use dcflow::dist::ServiceDist;
use dcflow::sim::network::{simulate_serial_iid, SimConfig};
use dcflow::util::bench::{bench, fmt_time, Csv};

fn main() {
    println!("== FIG2: serial composition tail growth (10..50 x Exp(1)) ==");
    let ns = [10usize, 20, 30, 40, 50];
    let (g, dt) = (4096usize, 100.0 / 4096.0); // grid to t=100
    let d = ServiceDist::exponential(1.0);

    let mut cdf_csv = Csv::new("fig2_cdf", "t,n10,n20,n30,n40,n50");
    let mut pdf_csv = Csv::new("fig2_pdf", "t,n10,n20,n30,n40,n50");
    let mut mom_csv = Csv::new(
        "fig2_moments",
        "n,mean_analytic,var_analytic,mean_grid,var_grid,mean_sim,var_sim",
    );

    let base = d.pdf_grid(dt, g);
    let mut curves: Vec<Vec<f64>> = Vec::new();
    let mut grid_moments = Vec::new();
    for &n in &ns {
        let stack: Vec<Vec<f64>> = (0..n).map(|_| base.clone()).collect();
        let pdf = serial_compose(&stack, dt);
        grid_moments.push(moments(&pdf, dt));
        curves.push(pdf);
    }

    // verify against Erlang closed form + DES
    println!(
        "{:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "n", "mean(anal)", "var(anal)", "mean(grid)", "var(grid)", "mean(sim)", "var(sim)"
    );
    let cfg = SimConfig {
        n_tasks: 100_000,
        warmup: 0,
        seed: 20260711,
        queueing: false,
    };
    for (i, &n) in ns.iter().enumerate() {
        let (gm, gv) = grid_moments[i];
        let sim = simulate_serial_iid(1.0, n, &cfg);
        println!(
            "{n:>4} {:>12.3} {:>12.3} {gm:>12.3} {gv:>12.3} {:>12.3} {:>12.3}",
            n as f64, n as f64, sim.mean, sim.var
        );
        mom_csv.rowf(&[n as f64, n as f64, n as f64, gm, gv, sim.mean, sim.var]);
        // shape assertions: Erlang truth
        assert!((gm - n as f64).abs() < 0.05 * n as f64, "grid mean off");
        assert!((sim.mean - n as f64).abs() < 0.05 * n as f64, "sim mean off");
        // spot-check the CDF curve against closed form
        for k in (0..g).step_by(509) {
            let t = k as f64 * dt;
            let want = erlang_cdf(t, n as u32, 1.0);
            let got = cdf_from_pdf(&curves[i], dt)[k];
            assert!((got - want).abs() < 0.01, "n={n} t={t}: {got} vs {want}");
        }
    }

    // dump curves
    for k in (0..g).step_by(8) {
        let t = k as f64 * dt;
        let mut c_row = vec![t];
        let mut p_row = vec![t];
        for pdf in &curves {
            c_row.push(cdf_from_pdf(pdf, dt)[k]);
            p_row.push(pdf[k]);
        }
        cdf_csv.rowf(&c_row);
        pdf_csv.rowf(&p_row);
        let _ = erlang_pdf(t, 10, 1.0); // keep closed form exercised
    }
    cdf_csv.flush();
    pdf_csv.flush();
    mom_csv.flush();

    // perf: time of one 50-stage composition (the hot analytic path)
    let stack: Vec<Vec<f64>> = (0..50).map(|_| base.clone()).collect();
    let t = bench(2, 10, || serial_compose(&stack, dt));
    println!(
        "\nperf: 50-stage serial compose on {g}-point grid: {} / iter ({:.1} it/s)",
        fmt_time(t.mean_s),
        t.per_sec()
    );
    println!("FIG2 OK: mean and variance grow linearly with serial depth");
}
